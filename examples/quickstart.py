"""Quickstart: the paper's running example + a first real index.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import alphabet as al
from repro.core.bwt import bwt
from repro.core.fm_index import PAD
from repro.core.pipeline import build_index

import jax.numpy as jnp


def banana():
    """Figures 1-2 of the paper: S = BANANA$.

    The paper's figure sorts '$' as the LARGEST symbol (giving BNN$AAA,
    I=3); we use the modern FM-index convention '$' smallest, giving the
    equally valid BWT ANNB$AA, I=4 — same rotation multiset, and the
    inverse transform recovers BANANA$ either way (paper: the sentinel
    choice "is unimportant for the purpose of the algorithm").
    """
    s = al.append_sentinel(al.encode_str("BANANA"))
    sigma = al.sigma_of(s)
    b, row = bwt(jnp.asarray(s), sigma)
    shown = "".join(
        "$" if t == al.SENTINEL else chr(t - 1) for t in np.asarray(b)
    )
    print(f"bwt(BANANA$) = {shown}   I = {int(row)}   "
          f"(paper, $-largest convention: BNN$AAA, I=3)")
    assert shown == "ANNB$AA" and int(row) == 4


def first_index():
    rng = np.random.default_rng(0)
    text = rng.integers(1, 5, 5000).astype(np.int32)  # DNA-ish tokens 1..4
    index = build_index(text, sample_rate=64)

    queries = np.full((3, 8), PAD, np.int32)
    queries[0, :3] = text[100:103]     # guaranteed hit
    queries[1, :6] = text[2000:2006]   # guaranteed hit
    queries[2, :4] = [1, 1, 1, 1]      # maybe
    counts = np.asarray(index.count(queries))
    print(f"indexed {len(text)} tokens; query counts = {counts.tolist()}")
    assert counts[0] >= 1 and counts[1] >= 1


if __name__ == "__main__":
    banana()
    first_index()
    print("quickstart OK")
