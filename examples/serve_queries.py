"""Serving example: batched FM-index pattern counting (the index side) and
batched LM token decoding (the model side) from one process.

    PYTHONPATH=src python examples/serve_queries.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_reduced_config
from repro.core import alphabet as al
from repro.core.fm_index import PAD
from repro.core.pipeline import build_index
from repro.data.corpus import corpus
from repro.models import transformer as tf
from repro.sharding import single_device_context


def serve_fm(n=1 << 15, batch=256, rounds=5):
    toks = corpus("proteins", n)
    index = build_index(toks, sample_rate=64)
    s = al.append_sentinel(toks)
    rng = np.random.default_rng(0)
    lat = []
    for _ in range(rounds):
        pats = np.full((batch, 12), PAD, np.int32)
        for i in range(batch):
            L = rng.integers(3, 12)
            st = rng.integers(0, n - L - 1)
            pats[i, :L] = s[st : st + L]
        t0 = time.perf_counter()
        counts = np.asarray(index.count(pats))
        lat.append(time.perf_counter() - t0)
        assert (counts >= 1).all()  # all sampled from the corpus
    lat_ms = sorted(x * 1e3 for x in lat)
    print(
        f"FM serving: batch={batch} p50={lat_ms[len(lat_ms) // 2]:.1f}ms "
        f"-> {batch / min(lat):.0f} queries/s"
    )


def serve_lm(batch=4, prompt_len=8, gen=16):
    ctx = single_device_context()
    cfg = get_reduced_config("qwen2p5_3b")
    params = tf.init_model(cfg, jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(1)
    prompts = rng.integers(1, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)

    step = jax.jit(
        lambda p, c, t, pos: tf.decode_step(p, c, t, pos, cfg, ctx),
        donate_argnums=(1,),
    )
    cache = tf.init_cache(cfg, batch, prompt_len + gen, jnp.float32)
    out = []
    tok = jnp.asarray(prompts[:, :1])
    t0 = time.perf_counter()
    for pos in range(prompt_len + gen):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        if pos + 1 < prompt_len:
            tok = jnp.asarray(prompts[:, pos + 1 : pos + 2])
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(np.asarray(tok)[:, 0])
    dt = time.perf_counter() - t0
    toks_s = batch * (prompt_len + gen) / dt
    print(f"LM decode: {batch}x{prompt_len + gen} tokens, {toks_s:.0f} tok/s")
    assert len(out) == gen + 1


if __name__ == "__main__":
    serve_fm()
    serve_lm()
    print("serve_queries OK")
