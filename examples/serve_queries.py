"""Serving example: batched FM-index pattern counting (the index side) and
batched LM token decoding (the model side) from one process.

    PYTHONPATH=src python examples/serve_queries.py

Querying
--------
``FMQueryServer`` (serving/engine.py) is the production front door: it
micro-batches mixed count/locate requests into fixed-shape jit buckets over
an index built with SA sampling enabled::

    from repro.core.pipeline import build_index
    from repro.serving.engine import FMQueryServer

    index = build_index(tokens, sample_rate=64, sa_sample_rate=32)
    server = FMQueryServer(index, length_buckets=(8, 16, 32), locate_k=16)

    server.count([q1, q2, q3])        # -> np.ndarray of exact-match counts
    server.locate([q1], k=8)          # -> [positions per query]

    t_a = server.submit(q_a, "count")  # or: interleave kinds explicitly,
    t_b = server.submit(q_b, "locate") # flush once, read by ticket
    results = server.flush()
    results[t_b].positions
    print(server.throughput_report())  # queries/s across flushes

Counts come from kernel-backed backward search (bit-packed popcount rank
when sigma <= 16); ``locate`` LF-walks to the sampled suffix array, at most
``sa_sample_rate - 1`` rank batches per flush.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_reduced_config
from repro.core import alphabet as al
from repro.core.fm_index import PAD
from repro.core.pipeline import build_index
from repro.data.corpus import corpus
from repro.models import transformer as tf
from repro.sharding import single_device_context


def serve_fm(n=1 << 15, batch=256, rounds=5):
    from repro.configs.bwt_index import CONFIG as icfg
    from repro.serving.engine import FMQueryServer

    toks = corpus("proteins", n)
    index = build_index(toks, sample_rate=64,
                        sa_sample_rate=icfg.sa_sample_rate)
    s = al.append_sentinel(toks)
    rng = np.random.default_rng(0)
    server = FMQueryServer.from_config(index, icfg.replace(locate_k=8))
    lat = []
    for _ in range(rounds):
        pats = np.full((batch, 12), PAD, np.int32)
        for i in range(batch):
            L = rng.integers(3, 12)
            st = rng.integers(0, n - L - 1)
            pats[i, :L] = s[st : st + L]
        t0 = time.perf_counter()
        counts = np.asarray(index.count(pats))
        lat.append(time.perf_counter() - t0)
        assert (counts >= 1).all()  # all sampled from the corpus
    lat_ms = sorted(x * 1e3 for x in lat)
    print(
        f"FM serving: batch={batch} p50={lat_ms[len(lat_ms) // 2]:.1f}ms "
        f"-> {batch / min(lat):.0f} queries/s"
    )

    # mixed micro-batched traffic through the server front door
    queries = [s[st : st + 8] for st in rng.integers(0, n - 9, 32)]
    tickets = [server.submit(q, kind) for q, kind in
               zip(queries, ["count", "locate"] * 16)]
    results = server.flush()
    hits = results[tickets[1]].positions
    assert len(hits) >= 1 and np.array_equal(s[hits[0]:hits[0] + 8], queries[1])
    print(server.throughput_report())


def serve_lm(batch=4, prompt_len=8, gen=16):
    ctx = single_device_context()
    cfg = get_reduced_config("qwen2p5_3b")
    params = tf.init_model(cfg, jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(1)
    prompts = rng.integers(1, cfg.vocab_size, (batch, prompt_len)).astype(np.int32)

    step = jax.jit(
        lambda p, c, t, pos: tf.decode_step(p, c, t, pos, cfg, ctx),
        donate_argnums=(1,),
    )
    cache = tf.init_cache(cfg, batch, prompt_len + gen, jnp.float32)
    out = []
    tok = jnp.asarray(prompts[:, :1])
    t0 = time.perf_counter()
    for pos in range(prompt_len + gen):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        if pos + 1 < prompt_len:
            tok = jnp.asarray(prompts[:, pos + 1 : pos + 2])
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(np.asarray(tok)[:, 0])
    dt = time.perf_counter() - t0
    toks_s = batch * (prompt_len + gen) / dt
    print(f"LM decode: {batch}x{prompt_len + gen} tokens, {toks_s:.0f} tok/s")
    assert len(out) == gen + 1


if __name__ == "__main__":
    serve_fm()
    serve_lm()
    print("serve_queries OK")
