"""End-to-end corpus indexing: build a BWT/FM index over a synthetic
Pizza&Chili-style corpus, then run the two data-hygiene passes the LM
training pipeline uses (dedup + contamination screening).

    PYTHONPATH=src python examples/index_corpus.py [--kind dna] [--n 65536]

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise the
distributed build (both sort engines) on virtual devices.
"""

import argparse
import time

import numpy as np

import jax

from repro.core.dist_suffix_array import BITONIC, SAMPLESORT, DistSAConfig
from repro.core.pipeline import build_index
from repro.data.corpus import corpus
from repro.data.dedup import contamination_report, duplicate_window_mask


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="dna", choices=["dna", "proteins", "english"])
    ap.add_argument("--n", type=int, default=1 << 16)
    ap.add_argument("--engine", default=BITONIC, choices=[BITONIC, SAMPLESORT])
    args = ap.parse_args()

    toks = corpus(args.kind, args.n)
    # plant a duplicate: repeat a 512-token slice
    toks = np.concatenate([toks, toks[1000:1512]])

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("parts",)) if ndev > 1 else None
    t0 = time.time()
    index = build_index(
        toks, mesh, sample_rate=64,
        sa_config=DistSAConfig(engine=args.engine, capacity_factor=3.0),
    )
    print(
        f"built {args.kind} index over {len(toks)} tokens in "
        f"{time.time() - t0:.1f}s on {ndev} device(s) ({args.engine})"
    )

    t0 = time.time()
    mask = duplicate_window_mask(index, toks, window=64, stride=64)
    dup_frac = mask.mean()
    print(f"dedup: {dup_frac:.2%} of positions in duplicate windows "
          f"({time.time() - t0:.1f}s)")
    assert mask[1024:1400].any(), "planted duplicate not found"

    eval_seqs = [
        toks[5000:5200].copy(),                      # leaked from corpus
        np.full(128, 2, np.int32),                   # generic
        (corpus(args.kind, 256, seed=999) % 4) + 1,  # fresh
    ]
    rep = contamination_report(index, eval_seqs, probe_len=32)
    print(f"contamination: sequences {rep['contaminated']} leak into corpus")
    assert 0 in rep["contaminated"]
    print("index_corpus OK")


if __name__ == "__main__":
    main()
