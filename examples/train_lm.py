"""End-to-end training driver: BWT-index the corpus, dedup it, then train a
language model on the cleaned stream — the paper's index as a first-class
data-pipeline stage (DESIGN.md §3).

    PYTHONPATH=src python examples/train_lm.py                 # quick demo
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Presets (CPU wall-time is the constraint in this container; the same driver
scales to the production mesh via launch/train.py):
    demo : ~7M params,  seq 64,  ~2 min for 60 steps
    100m : ~124M params, seq 256, the assignment's "~100M for a few hundred
           steps" — prints a time estimate before starting.
"""

import argparse

import numpy as np

from repro.configs.base import get_reduced_config
from repro.data.corpus import corpus
from repro.data.dedup import build_corpus_index, duplicate_window_mask
from repro.data.loader import LoaderConfig, TokenLoader
from repro.models.transformer import count_params
from repro.sharding import single_device_context
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train

PRESETS = {
    "demo": dict(
        d_model=128, num_layers=4, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=512, vocab_size=512, seq=64, batch=8, steps=60,
    ),
    "100m": dict(
        d_model=768, num_layers=12, num_heads=12, num_kv_heads=4, head_dim=64,
        d_ff=3072, vocab_size=8192, seq=256, batch=8, steps=300,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--skip-dedup", action="store_true")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    steps = args.steps or p["steps"]

    cfg = get_reduced_config("qwen2p5_3b").replace(
        d_model=p["d_model"], num_layers=p["num_layers"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
    )
    print(f"model: {count_params(cfg) / 1e6:.1f}M params")

    # 1. corpus + BWT-index dedup (the paper's technique in the pipeline)
    toks = corpus("english", 1 << 17) % (p["vocab_size"] - 1) + 1
    drop_mask = None
    if not args.skip_dedup:
        index = build_corpus_index(toks[: 1 << 16], sample_rate=64)
        drop_mask = np.zeros(len(toks), bool)
        dm = duplicate_window_mask(index, toks[: 1 << 16], window=64, stride=256)
        drop_mask[: 1 << 16] = dm
        print(f"dedup: dropping {dm.mean():.2%} of sampled windows")

    loader = TokenLoader(
        toks, LoaderConfig(p["batch"], p["seq"], seed=0), drop_mask=drop_mask
    )

    # 2. train
    ctx = single_device_context()
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=steps),
        checkpoint_every=max(50, steps // 4),
        log_every=10,
    )
    res = train(cfg, ctx, tcfg, loader, steps, ckpt_dir=args.ckpt_dir,
                resume=args.resume)
    losses = res["losses"]
    print(
        f"loss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f} "
        f"over {len(losses)} steps"
    )
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "did not learn"
    print("train_lm OK")


if __name__ == "__main__":
    main()
