"""Compaction benchmark: rebuild-free BWT merges vs raw-token rebuild.

``SegmentedIndex.compact`` has three rebuild-free-capable strategies: the
pairwise interleave fold, the k-way interleave walk (one walk splices
every segment — no intermediate indexes), and the cost-model auto pick
(``strategy="merge"``, the serving default).  ``strategy="rebuild"``
re-sorts the run's raw tokens — the correctness oracle.  Each row of
``experiments/BENCH_compact.json`` times all of them over the same
catalog and asserts every strategy produces a bit-identical merged index
(``outputs_match``) and identical query answers (``answers_match``).
``speedup`` is rebuild time over the auto-picked strategy's time — the
regression gate (``scripts/check_bench_json.py``) fails any row where the
serving default loses to the rebuild.

``--smoke`` runs the 64 Ki scales at 2, 4, and 8 segments (the CI
regression gate rows).  The 2-segment row is the cold-start equal split;
the 4- and 8-segment rows use the steady-state serving shape — one large
accumulated segment plus a tail of fresh small appends (``SHAPES``),
which is the run ``maybe_compact`` actually folds between flushes.  The
shape matters: the sequential interleave walk visits every token *after*
the largest segment, so merges win exactly when the accumulated segment
dominates the run (and the k-way walk additionally avoids the pairwise
fold's per-intermediate splices as the tail widens).  An equal split at
high segment count is the merge-hostile case, and the cost model's job is
to route it to the rebuild instead — the planner's pick is recorded per
row as ``strategy``.  Full runs add more corpora and a 128 Ki scale.
Timings exclude compile: each strategy is warmed on a same-shape
throwaway catalog first, so the steady-state serving cost (the jit
programs are cached per power-of-two bucket) is what is measured.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import alphabet as al
from repro.core.fm_index import PAD, fm_mismatch
from repro.core.segments import SegmentedIndex
from repro.data.corpus import corpus

DEFAULT_JSON = os.path.join(
    os.path.dirname(__file__), "..", "experiments", "BENCH_compact.json"
)

SAMPLE_RATE = 32
SA_SAMPLE_RATE = 16

STRATEGIES = ("rebuild", "pairwise", "kway", "merge")

# catalog split per segment count, as corpus fractions.  2 segments:
# cold-start equal halves.  4/8 segments: the serving steady state — one
# accumulated segment holding most of the corpus plus fresh small appends
# (each flush adds a small segment; maybe_compact folds the run).
SHAPES = {
    2: (1 / 2, 1 / 2),
    4: (3 / 4, 1 / 8, 1 / 16, 1 / 16),
    8: (3 / 4, 1 / 16) + (1 / 32,) * 6,
}


def build_catalog(kind: str, n: int, n_segments: int) -> SegmentedIndex:
    toks = corpus(kind, n)
    sigma = al.sigma_of(al.append_sentinel(toks))
    seg = SegmentedIndex(sigma, sample_rate=SAMPLE_RATE,
                         sa_sample_rate=SA_SAMPLE_RATE)
    shape = SHAPES[n_segments]
    bounds = np.round(np.cumsum((0.0,) + shape) * len(toks)).astype(int)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        seg.append(toks[lo:hi])
    return seg


def snapshot(seg: SegmentedIndex):
    return list(seg.segments), seg._next_id


def restore(seg: SegmentedIndex, snap) -> None:
    seg.segments, seg._next_id = list(snap[0]), snap[1]
    seg._stacked_cache = None


def time_strategy(seg: SegmentedIndex, snap, strategy: str, repeats: int):
    best, merged, plan = float("inf"), None, None
    for _ in range(repeats):
        restore(seg, snap)
        t0 = time.perf_counter()
        m = seg.compact(strategy=strategy)
        jax.block_until_ready(seg.segments[0].index.fm.bwt)
        best = min(best, time.perf_counter() - t0)
        assert m >= 1, strategy
        merged = seg.segments[0].index.fm
        plan = seg.compact_last_plan
    return best, merged, plan


def bench_scale(kind: str, n: int, n_segments: int, repeats: int,
                rng) -> dict:
    seg = build_catalog(kind, n, n_segments)
    snap = snapshot(seg)

    # warm the jit programs (snapshot-restore resets the catalog, so the
    # warmup compaction hits the same pow2 bucket shapes the timed runs do)
    for strategy in STRATEGIES:
        restore(seg, snap)
        seg.compact(strategy=strategy)

    times, fms, plans = {}, {}, {}
    for strategy in STRATEGIES:
        times[strategy], fms[strategy], plans[strategy] = time_strategy(
            seg, snap, strategy, repeats
        )
    outputs_match = all(
        not fm_mismatch(fms[s], fms["rebuild"]) for s in STRATEGIES[1:]
    )
    assert seg.compact_fallbacks == 0, seg.compact_last_fallback_reason

    # answers must also be invariant across the compaction itself
    restore(seg, snap)
    B, L = 16, 8
    toks = np.concatenate([s.tokens for s in seg.segments])
    pats = np.full((B, L), PAD, np.int32)
    for b in range(B):
        m = int(rng.integers(2, L + 1))
        st = int(rng.integers(0, len(toks) - m))
        pats[b, :m] = toks[st : st + m]
    before = seg.count(pats)
    seg.compact(strategy="merge")
    answers_match = bool(np.array_equal(seg.count(pats), before))

    plan = plans["merge"]
    row = {
        "scenario": f"{kind}.{n}.{n_segments}seg",
        "n": int(n),
        "segments": int(n_segments),
        "merge_s": times["merge"],
        "pairwise_s": times["pairwise"],
        "kway_s": times["kway"],
        "rebuild_s": times["rebuild"],
        "speedup": times["rebuild"] / times["merge"],
        "strategy": plan["strategy"],
        "est_walk_steps": int(plan["est_walk_steps"]),
        "actual_walk_steps": int(plan["actual_walk_steps"]),
        "outputs_match": bool(outputs_match),
        "answers_match": answers_match,
    }
    print(
        f"{row['scenario']}: auto[{row['strategy']}] "
        f"{times['merge'] * 1e3:.1f}ms (pairwise "
        f"{times['pairwise'] * 1e3:.1f}ms, kway "
        f"{times['kway'] * 1e3:.1f}ms) vs rebuild "
        f"{times['rebuild'] * 1e3:.1f}ms -> {row['speedup']:.2f}x "
        f"(bit-identical: {outputs_match})"
    )
    return row


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="64 Ki rows at 2/4/8 segments (the CI gate)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default=DEFAULT_JSON,
                    help="output path ('' disables)")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    scales = [("dna", 1 << 16, 2), ("dna", 1 << 16, 4), ("dna", 1 << 16, 8)]
    if not args.smoke:
        scales += [("english", 1 << 16, 2), ("dna", 1 << 17, 2)]
    rows = [bench_scale(kind, n, k, args.repeats, rng)
            for kind, n, k in scales]

    bad = [r["scenario"] for r in rows
           if not (r["outputs_match"] and r["answers_match"])]
    if bad:
        raise SystemExit(f"compact_bench: CORRECTNESS FAILURE in {bad}")

    if args.json:
        payload = {
            "bench": "compact",
            "backend": jax.default_backend(),
            "rows": rows,
        }
        path = os.path.abspath(args.json)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
