"""Compaction benchmark: rebuild-free BWT merge vs raw-token rebuild.

``SegmentedIndex.compact(strategy="merge")`` splices per-segment BWTs via
the ``core.bwt_merge`` interleave walk (no suffix sorting);
``strategy="rebuild"`` re-sorts the run's raw tokens — the correctness
oracle.  Each row of ``experiments/BENCH_compact.json`` times both
strategies over the same catalog and asserts the two produce a
bit-identical merged index (``outputs_match``) and identical query answers
(``answers_match``).

``--smoke`` runs the 64 Ki two-segment scale (the CI regression gate row);
full runs add more scales and a multi-segment catalog.  Timings exclude
compile: each strategy is warmed on a same-shape throwaway catalog first,
so the steady-state serving cost (the jit programs are cached per
power-of-two bucket) is what is measured.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.core import alphabet as al
from repro.core.fm_index import PAD, fm_mismatch
from repro.core.segments import SegmentedIndex
from repro.data.corpus import corpus

DEFAULT_JSON = os.path.join(
    os.path.dirname(__file__), "..", "experiments", "BENCH_compact.json"
)

SAMPLE_RATE = 32
SA_SAMPLE_RATE = 16


def build_catalog(kind: str, n: int, n_segments: int) -> SegmentedIndex:
    toks = corpus(kind, n)
    sigma = al.sigma_of(al.append_sentinel(toks))
    seg = SegmentedIndex(sigma, sample_rate=SAMPLE_RATE,
                         sa_sample_rate=SA_SAMPLE_RATE)
    bounds = np.linspace(0, len(toks), n_segments + 1).astype(int)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        seg.append(toks[lo:hi])
    return seg


def snapshot(seg: SegmentedIndex):
    return list(seg.segments), seg._next_id


def restore(seg: SegmentedIndex, snap) -> None:
    seg.segments, seg._next_id = list(snap[0]), snap[1]
    seg._stacked_cache = None


def time_strategy(seg: SegmentedIndex, snap, strategy: str, repeats: int):
    best, merged = float("inf"), None
    for _ in range(repeats):
        restore(seg, snap)
        t0 = time.perf_counter()
        m = seg.compact(strategy=strategy)
        jax.block_until_ready(seg.segments[0].index.fm.bwt)
        best = min(best, time.perf_counter() - t0)
        assert m >= 1, strategy
        merged = seg.segments[0].index.fm
    return best, merged


def bench_scale(kind: str, n: int, n_segments: int, repeats: int,
                rng) -> dict:
    seg = build_catalog(kind, n, n_segments)
    snap = snapshot(seg)

    # warm the jit programs (snapshot-restore resets the catalog, so the
    # warmup compaction hits the same pow2 bucket shapes the timed runs do)
    for strategy in ("merge", "rebuild"):
        restore(seg, snap)
        seg.compact(strategy=strategy)

    rebuild_s, fm_rebuild = time_strategy(seg, snap, "rebuild", repeats)
    merge_s, fm_merge = time_strategy(seg, snap, "merge", repeats)
    outputs_match = not fm_mismatch(fm_merge, fm_rebuild)

    # answers must also be invariant across the compaction itself
    restore(seg, snap)
    B, L = 16, 8
    toks = np.concatenate([s.tokens for s in seg.segments])
    pats = np.full((B, L), PAD, np.int32)
    for b in range(B):
        m = int(rng.integers(2, L + 1))
        st = int(rng.integers(0, len(toks) - m))
        pats[b, :m] = toks[st : st + m]
    before = seg.count(pats)
    seg.compact(strategy="merge")
    answers_match = bool(np.array_equal(seg.count(pats), before))

    row = {
        "scenario": f"{kind}.{n}.{n_segments}seg",
        "n": int(n),
        "segments": int(n_segments),
        "merge_s": merge_s,
        "rebuild_s": rebuild_s,
        "speedup": rebuild_s / merge_s,
        "outputs_match": bool(outputs_match),
        "answers_match": answers_match,
    }
    print(
        f"{row['scenario']}: merge {merge_s * 1e3:.1f}ms vs rebuild "
        f"{rebuild_s * 1e3:.1f}ms -> {row['speedup']:.2f}x "
        f"(bit-identical: {outputs_match})"
    )
    return row


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="64 Ki two-segment row only (the CI gate)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default=DEFAULT_JSON,
                    help="output path ('' disables)")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    scales = [("dna", 1 << 16, 2)]
    if not args.smoke:
        scales += [("dna", 1 << 16, 4), ("english", 1 << 16, 2),
                   ("dna", 1 << 17, 2)]
    rows = [bench_scale(kind, n, k, args.repeats, rng)
            for kind, n, k in scales]

    bad = [r["scenario"] for r in rows
           if not (r["outputs_match"] and r["answers_match"])]
    if bad:
        raise SystemExit(f"compact_bench: CORRECTNESS FAILURE in {bad}")

    if args.json:
        payload = {
            "bench": "compact",
            "backend": jax.default_backend(),
            "rows": rows,
        }
        path = os.path.abspath(args.json)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
