"""FM-index query engine benchmark: packed-Pallas rank path vs jnp reference.

Compares the production query engine (bit-packed fused layout dispatched
through kernels/ops — Pallas popcount kernel on TPU, vectorised jnp
popcount fallback on CPU) against the unpacked jnp reference layout on
identical query batches, for both ``count`` (backward search) and
``locate`` (SA-sample LF-walk), plus a rank-kernel microbenchmark.  On real
TPU the fused kernel's single-row DMA per query is the win; off-TPU the
packed fallback still reads 8-16x fewer bytes per in-block count.

``--smoke`` runs a seconds-scale variant with parity assertions (CI).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as al
from repro.core.bwt import bwt_from_sa
from repro.core.fm_index import (
    PAD,
    build_fm_index,
    count,
    locate,
    locate_naive,
)
from repro.core.pipeline import prepare_tokens
from repro.core.suffix_array import suffix_array
from repro.data.corpus import corpus


def _bench(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def build_indexes(n, sample_rate=64, sa_sample_rate=32):
    """(packed index, unpacked reference index, text) over the same corpus —
    one SA/BWT build shared by both layouts."""
    toks = corpus("dna", n - 1)
    s, sigma = prepare_tokens(toks, sample_rate)
    s_dev = jnp.asarray(s)
    sa = suffix_array(s_dev, sigma)
    bwt_arr, row = bwt_from_sa(s_dev, sa)
    kw = dict(sa=sa, sa_sample_rate=sa_sample_rate)
    fm_packed = build_fm_index(bwt_arr, row, sigma, sample_rate, **kw)
    fm_ref = build_fm_index(bwt_arr, row, sigma, sample_rate, pack=False, **kw)
    assert fm_packed.bits, "dna corpus should bit-pack"
    return fm_packed, fm_ref, s, sa


def _query_batch(rng, s, B, pattern_len):
    pats = np.full((B, pattern_len), PAD, np.int32)
    lens = rng.integers(4, pattern_len + 1, B)
    for i, L in enumerate(lens):
        st = rng.integers(0, len(s) - L - 2)
        pats[i, :L] = s[st : st + L]  # mostly-hitting queries
    return jnp.asarray(pats)


def count_paths(n=1 << 16, batches=(64, 512), pattern_len=16, reps=5):
    """Packed vs reference ``count`` on identical batches; asserts parity."""
    fm_packed, fm_ref, s, _sa = build_indexes(n)
    rng = np.random.default_rng(0)
    rows = []
    for B in batches:
        pats = _query_batch(rng, s, B, pattern_len)
        got_p = np.asarray(count(fm_packed, pats))
        got_r = np.asarray(count(fm_ref, pats))
        assert np.array_equal(got_p, got_r), "packed/reference count mismatch"
        t_packed = _bench(lambda p: count(fm_packed, p), pats, reps=reps)
        t_ref = _bench(lambda p: count(fm_ref, p), pats, reps=reps)
        rows.append({
            "batch": B,
            "packed_us": t_packed * 1e6,
            "ref_us": t_ref * 1e6,
            "speedup": t_ref / t_packed,
            "qps_packed": B / t_packed,
        })
    return rows


def locate_path(n=1 << 14, B=32, pattern_len=12, k=64, reps=3):
    """Sampled-SA locate vs the full-SA oracle: exact-match assertion plus
    throughput of the production path."""
    fm_packed, _fm_ref, s, sa = build_indexes(n)
    rng = np.random.default_rng(1)
    pats = _query_batch(rng, s, B, pattern_len)
    pos, cnt = locate(fm_packed, pats, k)
    pos, cnt = np.asarray(pos), np.asarray(cnt)
    for i in range(B):
        want = np.asarray(locate_naive(fm_packed, sa, pats[i]))
        nocc = int((want < fm_packed.n).sum())
        assert cnt[i] == min(nocc, k)
        if nocc <= k:
            assert np.array_equal(pos[i, :nocc], want[:nocc]), i
    t = _bench(lambda p: locate(fm_packed, p, k), pats, reps=reps)
    return {"batch": B, "k": k, "us": t * 1e6, "qps": B / t, "match": True}


def kernel_microbench(nblocks=256, r=64, B=1024, reps=5, smoke=False):
    """rank_packed impls on one fused array: jnp fallback vs interpret-mode
    kernel (parity always; timing skipped for interpret in smoke mode)."""
    from repro.kernels import ops, ref
    from repro.kernels.rank_select import pack_words

    rng = np.random.default_rng(1)
    sigma, bits = 6, 4
    syms = rng.integers(0, sigma, nblocks * r).astype(np.int32)
    words = np.asarray(pack_words(jnp.asarray(syms), bits)).reshape(nblocks, -1)
    onehot = (syms.reshape(nblocks, r)[:, :, None] == np.arange(sigma)).sum(1)
    occ = np.concatenate(
        [np.zeros((1, sigma), np.int64), np.cumsum(onehot, 0)]
    )[:nblocks].astype(np.int32)
    fused = jnp.asarray(np.concatenate([occ, words], axis=1))
    bidx = jnp.asarray(rng.integers(0, nblocks, B).astype(np.int32))
    c = jnp.asarray(rng.integers(0, sigma, B).astype(np.int32))
    cut = jnp.asarray(rng.integers(0, r + 1, B).astype(np.int32))

    args = (fused, bidx, c, cut)
    kw = dict(bits=bits, sigma=sigma)
    want = np.asarray(ref.rank_packed_ref(*args, **kw))
    got_jnp = np.asarray(ops.rank_packed(*args, **kw, impl="jnp"))
    got_int = np.asarray(ops.rank_packed(*args, **kw, impl="interpret"))
    match = np.array_equal(want, got_jnp) and np.array_equal(want, got_int)
    t_jnp = _bench(lambda *a: ops.rank_packed(*a, **kw, impl="jnp"),
                   *args, reps=reps)
    t_int = (None if smoke else
             _bench(lambda *a: ops.rank_packed(*a, **kw, impl="interpret"),
                    *args, reps=max(1, reps // 2)))
    return {"jnp_us": t_jnp * 1e6,
            "interpret_us": None if t_int is None else t_int * 1e6,
            "match": bool(match)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI variant with parity assertions")
    args = ap.parse_args(argv)

    if args.smoke:
        # n stays at the full 1<<16: below ~64Ki symbols the whole unpacked
        # index is cache-resident and the packed layout has nothing to save
        count_kw = dict(n=1 << 16, batches=(64,), pattern_len=12, reps=3)
        locate_kw = dict(n=1 << 10, B=8, pattern_len=6, k=1 << 10, reps=1)
        kernel_kw = dict(nblocks=32, r=64, B=64, reps=2, smoke=True)
    else:
        count_kw, locate_kw, kernel_kw = {}, {}, {}

    print("fmbench,metric,value,derived")
    for r in count_paths(**count_kw):
        print(
            f"fmbench,count_b{r['batch']},{r['packed_us']:.0f},"
            f"ref_us={r['ref_us']:.0f};speedup={r['speedup']:.2f}x;"
            f"qps={r['qps_packed']:.0f}"
        )
    loc = locate_path(**locate_kw)
    print(
        f"fmbench,locate_b{loc['batch']}_k{loc['k']},{loc['us']:.0f},"
        f"qps={loc['qps']:.0f};match={loc['match']}"
    )
    k = kernel_microbench(**kernel_kw)
    extra = ("" if k["interpret_us"] is None
             else f";interpret_us={k['interpret_us']:.0f}")
    print(
        f"fmbench,rank_packed,{k['jnp_us']:.0f},"
        f"match={k['match']}{extra}"
    )
    print("fmbench OK")


if __name__ == "__main__":
    main()
