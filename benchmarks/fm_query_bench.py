"""FM-index query serving throughput + rank_select kernel comparison.

Derived columns: queries/second for batched backward search (the serving
path), and the Pallas rank_select kernel (interpret mode) vs its jnp oracle
on identical query batches — on real TPU the kernel's scalar-prefetch DMA
is the win; interpret mode only certifies correctness-at-speed parity.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as al
from repro.core.bwt import bwt
from repro.core.fm_index import PAD, build_fm_index, count
from repro.data.corpus import corpus


def _bench(fn, *args, reps=5):
    fn(*args).block_until_ready()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def query_throughput(n=1 << 16, batches=(64, 512), pattern_len=16):
    toks = corpus("dna", n - 1)
    s = al.append_sentinel(toks)
    sigma = al.sigma_of(s)
    b, row = bwt(jnp.asarray(s), sigma)
    fm = build_fm_index(b, row, sigma, sample_rate=64)
    rng = np.random.default_rng(0)
    rows = []
    for B in batches:
        pats = np.full((B, pattern_len), PAD, np.int32)
        lens = rng.integers(4, pattern_len + 1, B)
        for i, L in enumerate(lens):
            st = rng.integers(0, n - L - 2)
            pats[i, :L] = s[st : st + L]  # mostly-hitting queries
        t = _bench(lambda p: count(fm, p), jnp.asarray(pats))
        rows.append({"batch": B, "s_per_call": t, "qps": B / t})
    return rows


def kernel_vs_ref(nblocks=256, r=64, B=1024):
    from repro.kernels import ops, ref

    rng = np.random.default_rng(1)
    bwt_blocks = jnp.asarray(rng.integers(0, 6, (nblocks, r)).astype(np.int32))
    bidx = jnp.asarray(rng.integers(0, nblocks, B).astype(np.int32))
    c = jnp.asarray(rng.integers(0, 6, B).astype(np.int32))
    cut = jnp.asarray(rng.integers(0, r + 1, B).astype(np.int32))
    t_kernel = _bench(
        lambda *a: ops.rank_select(*a), bwt_blocks, bidx, c, cut
    )
    ref_jit = jax.jit(ref.rank_select_ref)
    t_ref = _bench(lambda *a: ref_jit(*a), bwt_blocks, bidx, c, cut)
    same = np.array_equal(
        np.asarray(ops.rank_select(bwt_blocks, bidx, c, cut)),
        np.asarray(ref_jit(bwt_blocks, bidx, c, cut)),
    )
    return {"kernel_us": t_kernel * 1e6, "ref_us": t_ref * 1e6,
            "match": bool(same)}


def main():
    print("fmbench,metric,value,derived")
    for r in query_throughput():
        print(
            f"fmbench,count_b{r['batch']},{r['s_per_call'] * 1e6:.0f},"
            f"qps={r['qps']:.0f}"
        )
    k = kernel_vs_ref()
    print(
        f"fmbench,rank_select_interpret,{k['kernel_us']:.0f},"
        f"ref_us={k['ref_us']:.0f};match={k['match']}"
    )


if __name__ == "__main__":
    main()
