"""Distributed-sort engine ablation: bitonic merge-exchange vs sample sort,
with and without fused pair keys.

Wall time on one CPU core is meaningless for collectives, so the DERIVED
metric is per-device collective traffic (parsed from the compiled HLO of an
8-virtual-device run, the same parser the roofline uses) plus single-device
local-sort wall time as the compute proxy.

The volumes verify the DESIGN.md §4 analysis: bitonic moves
m*log2(P)*(log2(P)+1)/2 per sort vs samplesort's ~(beta+1)*m, so samplesort
wins on traffic at P >= 8 unless skew forces capacity retries.  The
``*_fused`` rows sort one packed uint32 key word + payload instead of two
int32 keys + payload (core.keypack): 2/3 the operands, 2/3 the shuffle
bytes.  Local rows compare lax.sort against the radix engine on the same
fused keys.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

_PROBE = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.dist_sort import ShardInfo, bitonic_sort_sharded, samplesort_sharded
from repro.launch.roofline import collective_bytes

P_DEV = 8
M = 1 << 12
info = ShardInfo("parts", P_DEV, M)
mesh = jax.make_mesh((P_DEV,), ("parts",))

def bitonic(a, b, c):
    return bitonic_sort_sharded(info, (a, b, c), num_keys=2)

def sample(a, b, c):
    r = samplesort_sharded(info, (a, b, c), num_keys=2, capacity_factor=2.0)
    return r.operands

# fused-key variants: one uint32 key word + index payload (core.keypack
# packing for n <= 65535) instead of two int32 keys
def bitonic_fused(k, c):
    return bitonic_sort_sharded(info, (k, c), num_keys=1)

def sample_fused(k, c):
    r = samplesort_sharded(info, (k, c), num_keys=1, capacity_factor=2.0)
    return r.operands

out = {}
CASES = (
    ("bitonic", bitonic, (jnp.int32,) * 3),
    ("samplesort", sample, (jnp.int32,) * 3),
    ("bitonic_fused", bitonic_fused, (jnp.uint32, jnp.int32)),
    ("samplesort_fused", sample_fused, (jnp.uint32, jnp.int32)),
)
for name, fn, dtypes in CASES:
    f = jax.jit(shard_map(fn, mesh=mesh,
                          in_specs=(P("parts"),) * len(dtypes),
                          out_specs=(P("parts"),) * len(dtypes)))
    args = [jax.ShapeDtypeStruct((P_DEV * M,), dt,
            sharding=jax.sharding.NamedSharding(mesh, P("parts")))
            for dt in dtypes]
    compiled = f.lower(*args).compile()
    stats = collective_bytes(compiled.as_text())
    out[name] = {"bytes_per_device": stats.total_bytes,
                 "counts": stats.counts}
print(json.dumps(out))
"""

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "src")
)


def collective_volumes():
    # resolve src relative to THIS file (not the caller's cwd) and hand it
    # to the subprocess via PYTHONPATH, so the probe imports `repro` no
    # matter where the benchmark is invoked from
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = _SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", _PROBE], capture_output=True, text=True,
        timeout=600, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    import json

    return json.loads(proc.stdout.strip().splitlines()[-1])


def local_sort_times(n=1 << 18, reps=3):
    """Single-device local-sort compute proxies: the seed 3-operand
    2-key sort vs the fused 1-key layouts (compare and radix engines)."""
    from repro.kernels import ops as kernel_ops

    rng = np.random.default_rng(0)
    k1 = jnp.asarray(rng.integers(0, 1 << 15, n).astype(np.int32))
    k2 = jnp.asarray(rng.integers(0, 1 << 15, n).astype(np.int32))
    fused = jnp.asarray(
        ((np.asarray(k1).astype(np.uint32) << 16)
         | np.asarray(k2).astype(np.uint32))
    )
    pay = jnp.arange(n, dtype=jnp.int32)
    cases = {
        "local_3op_compare": (
            jax.jit(lambda a, b, c: jax.lax.sort((a, b, c), num_keys=2)),
            (k1, k2, pay)),
        "local_fused_compare": (
            jax.jit(lambda k, c: jax.lax.sort((k, c), num_keys=1)),
            (fused, pay)),
        "local_fused_radix": (
            lambda k, c: kernel_ops.radix_sort(
                (k, c), num_keys=1, key_bits=(31,)),
            (fused, pay)),
    }
    out = {}
    for name, (f, args) in cases.items():
        f(*args)[0].block_until_ready()
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            f(*args)[0].block_until_ready()
            ts.append(time.perf_counter() - t0)
        out[name] = min(ts)
    return out


def main():
    vols = collective_volumes()
    locals_ = local_sort_times()
    print("sortbench,engine,bytes_per_device,collective_ops,local_sort_us")
    for eng, d in vols.items():
        nops = sum(d["counts"].values())
        print(f"sortbench,{eng},{d['bytes_per_device']},{nops},-")
    for name, t in locals_.items():
        print(f"sortbench,{name},-,-,{t * 1e6:.0f}")


if __name__ == "__main__":
    main()
