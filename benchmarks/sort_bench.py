"""Distributed-sort engine ablation: bitonic merge-exchange vs sample sort.

Wall time on one CPU core is meaningless for collectives, so the DERIVED
metric is per-device collective traffic (parsed from the compiled HLO of an
8-virtual-device run, the same parser the roofline uses) plus single-device
local-sort wall time as the compute proxy.

The volumes verify the DESIGN.md §4 analysis: bitonic moves
m*log2(P)*(log2(P)+1)/2 per sort vs samplesort's ~(beta+1)*m, so samplesort
wins on traffic at P >= 8 unless skew forces capacity retries.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

_PROBE = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
sys.path.insert(0, os.path.join(os.getcwd(), "src"))
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.dist_sort import ShardInfo, bitonic_sort_sharded, samplesort_sharded
from repro.launch.roofline import collective_bytes

P_DEV = 8
M = 1 << 12
info = ShardInfo("parts", P_DEV, M)
mesh = jax.make_mesh((P_DEV,), ("parts",))

def bitonic(a, b, c):
    return bitonic_sort_sharded(info, (a, b, c), num_keys=2)

def sample(a, b, c):
    r = samplesort_sharded(info, (a, b, c), num_keys=2, capacity_factor=2.0)
    return r.operands

out = {}
for name, fn, nout in (("bitonic", bitonic, 3), ("samplesort", sample, 3)):
    f = jax.jit(shard_map(fn, mesh=mesh,
                          in_specs=(P("parts"),) * 3,
                          out_specs=(P("parts"),) * nout))
    args = [jax.ShapeDtypeStruct((P_DEV * M,), jnp.int32,
            sharding=jax.sharding.NamedSharding(mesh, P("parts")))] * 3
    compiled = f.lower(*args).compile()
    stats = collective_bytes(compiled.as_text())
    out[name] = {"bytes_per_device": stats.total_bytes,
                 "counts": stats.counts}
print(json.dumps(out))
"""


def collective_volumes():
    proc = subprocess.run(
        [sys.executable, "-c", _PROBE], capture_output=True, text=True,
        timeout=600,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
    )
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    import json

    return json.loads(proc.stdout.strip().splitlines()[-1])


def local_sort_time(n=1 << 18, reps=3):
    rng = np.random.default_rng(0)
    k1 = jnp.asarray(rng.integers(0, 1 << 30, n).astype(np.int32))
    k2 = jnp.asarray(rng.integers(0, 1 << 30, n).astype(np.int32))
    pay = jnp.arange(n, dtype=jnp.int32)
    f = jax.jit(lambda a, b, c: jax.lax.sort((a, b, c), num_keys=2))
    f(k1, k2, pay)[0].block_until_ready()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        f(k1, k2, pay)[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def main():
    vols = collective_volumes()
    t_local = local_sort_time()
    print("sortbench,engine,bytes_per_device,collective_ops,local_sort_us")
    for eng, d in vols.items():
        nops = sum(d["counts"].values())
        print(
            f"sortbench,{eng},{d['bytes_per_device']},{nops},"
            f"{t_local * 1e6:.0f}"
        )


if __name__ == "__main__":
    main()
