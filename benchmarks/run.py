"""Benchmark harness: one section per paper table/figure + system ablations.

Prints ``name,us_per_call,derived`` CSV rows (plus per-bench headers).

  table2     paper Table 2 — ours vs Menon et al. competitor (wall time),
             plus fast-vs-seed build speedup; writes BENCH_build.json
  buildjson  machine-readable build trajectory from BENCH_build.json
  sortbench  DESIGN.md §4 sort-engine ablation (collective volume, derived;
             fused-key and radix local-sort variants)
  fmbench    FM-index serving throughput + rank_select kernel
  servebench async frontend load test (closed/open/overload); writes
             BENCH_serve.json
  compactbench  BWT-merge vs rebuild compaction (bit-identity asserted);
             writes BENCH_compact.json
  roofline   index-build + LM roofline terms (from dry-run JSONs, if present)
"""

from __future__ import annotations

import glob
import json
import os


def _roofline_section():
    out_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")
    files = sorted(glob.glob(os.path.join(out_dir, "*.json")))
    if not files:
        print("roofline,none,0,run `python -m repro.launch.dryrun` first")
        return
    print("roofline,cell,step_time_us,bottleneck;compute_s;memory_s;collective_s")
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        if r.get("status") != "compiled":
            continue
        roof = r.get("roofline", {})
        print(
            f"roofline,{r['arch']}__{r['shape']}__{r['mesh']},"
            f"{roof.get('step_time_s', 0) * 1e6:.0f},"
            f"{roof.get('bottleneck', '-')};{roof.get('compute_s', 0):.4f};"
            f"{roof.get('memory_s', 0):.4f};{roof.get('collective_s', 0):.4f}"
        )


def _build_json_section():
    from .table2_bwt import DEFAULT_JSON

    if not os.path.exists(DEFAULT_JSON):
        print("buildjson,none,0,table2 writes it")
        return
    with open(DEFAULT_JSON) as fh:
        payload = json.load(fh)
    print("buildjson,input,ours_s,build_speedup,rounds;skipped;active_frac0")
    for r in payload.get("rows", []):
        frac0 = r["active_frac"][0] if r["active_frac"] else 0.0
        print(
            f"buildjson,{r['input']},{r['ours_s']:.4f},"
            f"{r['build_speedup']:.2f},"
            f"{r['rounds_executed']};{r['rounds_skipped']};{frac0:.4f}"
        )


def main() -> None:
    from . import (
        compact_bench,
        fm_query_bench,
        serve_bench,
        sort_bench,
        table2_bwt,
    )

    table2_bwt.main([])
    _build_json_section()
    sort_bench.main()
    fm_query_bench.main([])
    serve_bench.main([])
    compact_bench.main([])
    _roofline_section()


if __name__ == "__main__":
    main()
