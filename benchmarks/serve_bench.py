"""Serving-frontend load generator: closed-loop + open-loop arrival, mixed
count/locate, against the async admission-controlled frontend.

Three scenarios per scale, each a row of ``experiments/BENCH_serve.json``:

* ``closed``   — N client threads, each submits and waits (classic
  closed-loop saturation: measures sustained QPS and per-bucket p50/p99
  with backpressure from the clients themselves).
* ``open``     — requests arrive on a fixed-rate schedule regardless of
  completions (open-loop: what a cloud frontend actually sees).  The rate
  is set from the closed-loop measurement so the system runs near — but
  under — saturation.
* ``overload`` — open-loop far above capacity against a tiny admission
  queue: the frontend must shed (``Rejected``) rather than fall over, and
  every *admitted* request must still be answered correctly.

Every scenario cross-checks frontend answers against direct index calls
(``outputs_match`` — a fast wrong server must be loud), and rows carry
per-bucket p50/p99 plus flattened worst-bucket fields so
``scripts/check_bench_json.py`` can regression-compare smoke runs.

``--smoke`` shrinks the corpus and request counts for CI; smoke rows are
ALSO produced by full runs (suffix ``_smoke``) so the committed baseline
always contains the rows CI compares against.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time

import jax
import numpy as np

from repro.core import alphabet as al
from repro.core.segments import SegmentedIndex
from repro.data.corpus import corpus
from repro.serving.engine import FMQueryServer
from repro.serving.frontend import AsyncQueryFrontend, Rejected

DEFAULT_JSON = os.path.join(
    os.path.dirname(__file__), "..", "experiments", "BENCH_serve.json"
)

LOCATE_FRAC = 0.2
LOCATE_K = 4


def build_segmented(kind: str, n: int, n_segments: int,
                    sample_rate=32, sa_sample_rate=16):
    """A segmented index over an n-token corpus (segment-parallel fan-out
    is the serving default), plus the raw sentinel-terminated text."""
    toks = corpus(kind, n)
    sigma = al.sigma_of(al.append_sentinel(toks))
    seg = SegmentedIndex(sigma, sample_rate=sample_rate,
                         sa_sample_rate=sa_sample_rate)
    bounds = np.linspace(0, len(toks), n_segments + 1).astype(int)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        seg.append(toks[lo:hi])
    return seg, toks


def make_requests(rng, toks, n_requests, buckets, locate_frac=LOCATE_FRAC):
    """Mixed workload: (pattern, kind) pairs with lengths spread across the
    server's jit buckets (patterns sampled from the corpus, so counts are
    nonzero often enough to exercise locate walks)."""
    reqs = []
    max_len = buckets[-1]
    for _ in range(n_requests):
        L = int(rng.integers(2, max_len + 1))
        st = int(rng.integers(0, len(toks) - L))
        kind = "locate" if rng.random() < locate_frac else "count"
        reqs.append((np.ascontiguousarray(toks[st : st + L]), kind))
    return reqs


def expected_results(index, reqs, k=LOCATE_K):
    """Direct (unqueued) answers for every request, via one padded batch
    per kind — the oracle for ``outputs_match``."""
    from repro.core.fm_index import PAD

    L = max(len(p) for p, _ in reqs)
    pats = np.full((len(reqs), L), PAD, np.int32)
    for i, (p, _) in enumerate(reqs):
        pats[i, : len(p)] = p
    counts = np.asarray(index.count(pats), np.int64)
    pos, _ = index.locate(pats, k)
    return counts, np.asarray(pos, np.int64)


def check_results(reqs, results, counts, pos, k=LOCATE_K):
    """True iff every non-shed frontend result equals the direct answer."""
    ok = True
    for i, ((_, kind), res) in enumerate(zip(reqs, results)):
        if isinstance(res, Rejected):
            continue
        if res.count != min(counts[i], k if kind == "locate" else counts[i]):
            ok = False
        if kind == "locate":
            want = pos[i][: res.count]
            if not np.array_equal(np.asarray(res.positions, np.int64), want):
                ok = False
    return ok


def run_closed(frontend, reqs, clients):
    """Closed loop: ``clients`` threads round-robin the request list, each
    waiting for its result before submitting the next."""
    results = [None] * len(reqs)
    t0 = time.perf_counter()

    def worker(start):
        for i in range(start, len(reqs), clients):
            pat, kind = reqs[i]
            k = LOCATE_K if kind == "locate" else None
            results[i] = frontend.submit(pat, kind, k=k).result(timeout=300)

    threads = [threading.Thread(target=worker, args=(c,))
               for c in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results, time.perf_counter() - t0


def run_open(frontend, reqs, target_qps=None):
    """Open loop: submit on a fixed-rate schedule (no waiting for results),
    then gather.  ``target_qps=None`` is an unpaced burst — every request
    arrives as fast as the producer can enqueue, the worst overload case.
    Falling behind the schedule is allowed — arrival times just bunch up,
    which is exactly the overload behaviour being measured."""
    futs = []
    interval = 1.0 / target_qps if target_qps else 0.0
    t0 = time.perf_counter()
    for i, (pat, kind) in enumerate(reqs):
        if interval:
            delay = t0 + i * interval - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
        k = LOCATE_K if kind == "locate" else None
        futs.append(frontend.submit(pat, kind, k=k))
    results = [f.result(timeout=300) for f in futs]
    return results, time.perf_counter() - t0


def warm_shapes(server, rng, toks, buckets, sizes):
    """Compile every jit program the scenarios can hit — one direct flush
    per (kind, length bucket, pow2 batch bucket), so scenario latencies
    measure serving, not compilation (chunks of any size <= max(sizes) pad
    to one of these shapes)."""
    for size in sizes:
        for L in buckets:
            for kind in ("count", "locate"):
                for _ in range(size):
                    st = int(rng.integers(0, len(toks) - L))
                    server.submit(toks[st : st + L], kind,
                                  k=LOCATE_K if kind == "locate" else None)
                server.flush()


def _flatten_buckets(metrics):
    """Worst-bucket p50/p99 per kind, flattened for the regression check."""
    out = {}
    for kind in ("count", "locate"):
        rows = [b for key, b in metrics["buckets"].items()
                if key.startswith(kind + "/") and b["completed"]]
        if rows:
            out[f"{kind}_p50_ms"] = max(r["p50_ms"] for r in rows)
            out[f"{kind}_p99_ms"] = max(r["p99_ms"] for r in rows)
    return out


def bench_scale(label, kind, n, n_segments, n_requests, clients, cfg, rng):
    """All three scenarios at one corpus scale -> list of row dicts."""
    seg, toks = build_segmented(kind, n, n_segments)
    buckets = cfg.serve_length_buckets
    max_batch = cfg.serve_max_batch
    slo = {"count": cfg.serve_slo_p99_ms,
           "locate": cfg.serve_slo_p99_ms_locate}
    rows = []

    def frontend(max_queue, max_wait_ms=None):
        server = FMQueryServer(seg, length_buckets=buckets,
                               max_batch=max_batch, locate_k=LOCATE_K)
        return AsyncQueryFrontend(
            server, max_queue=max_queue, slo_p99_ms=slo,
            max_wait_ms=cfg.serve_max_wait_ms
            if max_wait_ms is None else max_wait_ms,
        )

    sizes = [1 << i for i in range((max_batch).bit_length())]  # 1..max_batch
    warm_shapes(FMQueryServer(seg, length_buckets=buckets,
                              max_batch=max_batch, locate_k=LOCATE_K),
                rng, toks, buckets, sizes)

    base = {"input": f"{kind}.{n}", "n": int(n), "segments": n_segments,
            "locate_frac": LOCATE_FRAC}

    # closed loop
    reqs = make_requests(rng, toks, n_requests, buckets)
    counts, pos = expected_results(seg, reqs)
    with frontend(1 << 16) as fe:
        results, wall = run_closed(fe, reqs, clients)
        m = fe.metrics()
    closed_qps = len(reqs) / wall
    rows.append({**base, "scenario": f"closed{label}", "mode": "closed",
                 "clients": clients, "requests": len(reqs),
                 "wall_s": wall, "qps": closed_qps,
                 "admitted": m["admitted"], "rejected": m["rejected"],
                 "shed_frac": m["shed_frac"],
                 "outputs_match": check_results(reqs, results, counts, pos),
                 **_flatten_buckets(m), "buckets": m["buckets"]})

    # open loop at ~70% of measured closed-loop capacity
    reqs = make_requests(rng, toks, n_requests, buckets)
    counts, pos = expected_results(seg, reqs)
    target = max(closed_qps * 0.7, 1.0)
    with frontend(1 << 16) as fe:
        results, wall = run_open(fe, reqs, target)
        m = fe.metrics()
    rows.append({**base, "scenario": f"open{label}", "mode": "open",
                 "target_qps": target, "requests": len(reqs),
                 "wall_s": wall, "qps": len(reqs) / wall,
                 "admitted": m["admitted"], "rejected": m["rejected"],
                 "shed_frac": m["shed_frac"],
                 "outputs_match": check_results(reqs, results, counts, pos),
                 **_flatten_buckets(m), "buckets": m["buckets"]})

    # overload: an unpaced burst into a tiny admission queue -> must shed,
    # not crash, and every admitted answer must still be exact
    reqs = make_requests(rng, toks, n_requests, buckets)
    counts, pos = expected_results(seg, reqs)
    with frontend(max_queue=max(clients, 8), max_wait_ms=0.5) as fe:
        results, wall = run_open(fe, reqs, None)
        m = fe.metrics()
    shed = sum(isinstance(r, Rejected) for r in results)
    # no "qps" on the overload row: admitted/wall there is a ratio of two
    # burst-timing artifacts (queue-depth slip vs 1-2 flush drains) and
    # regression-gating it across machines would flake; the row's signal
    # is shed_frac > 0 with outputs_match on the admitted remainder
    rows.append({**base, "scenario": f"overload{label}", "mode": "burst",
                 "target_qps": None, "requests": len(reqs),
                 "wall_s": wall, "drain_rate": (len(reqs) - shed) / wall,
                 "admitted": m["admitted"], "rejected": m["rejected"],
                 "shed_frac": m["shed_frac"],
                 "outputs_match": check_results(reqs, results, counts, pos),
                 **_flatten_buckets(m), "buckets": m["buckets"]})
    return rows


def main(argv=None):
    from repro.configs.bwt_index import CONFIG, reduced

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run with assertions (CI)")
    ap.add_argument("--json", default=DEFAULT_JSON,
                    help="output path ('' skips the write)")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    rows = []
    # smoke rows run in BOTH modes, so the committed full-run baseline
    # contains the rows CI's smoke run is compared against
    cfg = reduced().replace(serve_length_buckets=(4, 8), serve_max_batch=8)
    rows += bench_scale("_smoke", "dna", 1 << 12, 3, 160, 4, cfg, rng)
    if not args.smoke:
        cfg = CONFIG.replace(serve_length_buckets=(8, 16, 32),
                             serve_max_batch=32)
        rows += bench_scale("", "dna", 1 << 16, 8, 1536, 8, cfg, rng)

    payload = {"bench": "serve_frontend", "backend": jax.default_backend(),
               "rows": rows}
    for r in rows:
        rate = r.get("qps", r.get("drain_rate"))
        print(
            f"servebench,{r['scenario']},{r['input']},qps={rate:.0f},"
            f"shed={r['shed_frac']:.2f},match={r['outputs_match']}"
        )
    if args.smoke:
        assert all(r["outputs_match"] for r in rows), "frontend != direct"
        over = [r for r in rows if r["scenario"].startswith("overload")]
        assert all(r["rejected"] > 0 for r in over), "overload never shed"
        assert all(r["admitted"] == r["requests"] - r["rejected"]
                   for r in rows)
    if args.json:
        path = os.path.abspath(args.json)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
