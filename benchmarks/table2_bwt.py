"""Paper Table 2: BWT construction — our prefix doubling vs the Menon et al.
competitor, on PROTEINS / DNA / ENGLISH corpora.

The paper ran 48 Spark nodes on up-to-1GB Pizza&Chili files; this container
is one CPU core, so we run CPU-feasible sizes of statistically similar
synthetic corpora (data/corpus.py) and verify the paper's CLAIMS:
  (1) ours beats the competitor at every size,
  (2) the gap GROWS with input size (competitor passes ~ LCP/K, ours
      ~ log2 n),
  (3) both produce identical, oracle-correct BWTs.
Cluster-scale behaviour is covered by the dry-run roofline of the
``bwt_index`` config (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as al
from repro.core.bwt import bwt_from_sa
from repro.core.competitor import suffix_array_rpgi
from repro.core.suffix_array import suffix_array
from repro.data.corpus import corpus


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready()  # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def run(sizes=(1 << 14, 1 << 16), kinds=("proteins", "dna", "english")):
    rows = []
    for kind in kinds:
        for n in sizes:
            toks = corpus(kind, n - 1)
            s = jnp.asarray(al.append_sentinel(toks))
            sigma = al.sigma_of(np.asarray(s))

            ours = jax.jit(
                lambda t: bwt_from_sa(t, suffix_array(t, sigma))
            )
            comp = jax.jit(
                lambda t: bwt_from_sa(t, suffix_array_rpgi(t))
            )
            t_ours = _time(ours, s)
            t_comp = _time(comp, s)

            b1, r1 = ours(s)
            b2, r2 = comp(s)
            match = bool(
                np.array_equal(np.asarray(b1), np.asarray(b2))
                and int(r1) == int(r2)
            )
            rows.append({
                "input": f"{kind}.{n}",
                "ours_s": t_ours,
                "competitor_s": t_comp,
                "speedup": t_comp / t_ours,
                "outputs_match": match,
            })
    return rows


def main():
    print("table2,input,ours_s,competitor_s,speedup,outputs_match")
    for r in run():
        print(
            f"table2,{r['input']},{r['ours_s']:.4f},{r['competitor_s']:.4f},"
            f"{r['speedup']:.2f},{r['outputs_match']}"
        )


if __name__ == "__main__":
    main()
