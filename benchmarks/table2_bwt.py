"""Paper Table 2: BWT construction — our prefix doubling vs the Menon et al.
competitor, on PROTEINS / DNA / ENGLISH corpora.

The paper ran 48 Spark nodes on up-to-1GB Pizza&Chili files; this container
is one CPU core, so we run CPU-feasible sizes of statistically similar
synthetic corpora (data/corpus.py) and verify the paper's CLAIMS:
  (1) ours beats the competitor at every size,
  (2) the gap GROWS with input size (competitor passes ~ LCP/K, ours
      ~ log2 n),
  (3) both produce identical, oracle-correct BWTs.

Since PR 2 "ours" is the fused-key fast builder (packed q-gram init +
active-suffix discarding + fused pair keys); the seed single-jit prefix
doubling is timed alongside as ``baseline`` so the build speedup is
measured end-to-end every run (acceptance: >= 2x at the largest size,
identical BWT output; measured 2.35-2.61x on the 64 Ki corpora, with
3-5 doubling rounds skipped by the q-gram init).

Emits ``BENCH_build.json`` (sizes, wall times, rounds executed/skipped,
per-round active fractions) so the perf trajectory is machine-readable —
``benchmarks/run.py`` includes it in the report.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as al
from repro.core.bwt import bwt_from_sa
from repro.core.competitor import suffix_array_rpgi
from repro.core.suffix_array import suffix_array, suffix_array_fast
from repro.data.corpus import corpus

DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "experiments",
    "BENCH_build.json",
)


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready()  # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts)


def _time_fast(s, sigma, reps=3):
    """Time the host-driven fast builder (not a single jit: the round loop
    reads back the active count to shrink the sort capacity)."""
    def build():
        sa, stats = suffix_array_fast(s, sigma)
        return bwt_from_sa(s, sa), stats
    (out, stats) = build()       # warm: compiles every capacity bucket
    out[0].block_until_ready()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        (out, stats) = build()
        out[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts), out, stats


def run(sizes=(1 << 14, 1 << 16), kinds=("proteins", "dna", "english"),
        reps=3):
    rows = []
    for kind in kinds:
        for n in sizes:
            toks = corpus(kind, n - 1)
            s = jnp.asarray(al.append_sentinel(toks))
            sigma = al.sigma_of(np.asarray(s))

            baseline = jax.jit(
                lambda t: bwt_from_sa(t, suffix_array(t, sigma))
            )
            comp = jax.jit(
                lambda t: bwt_from_sa(t, suffix_array_rpgi(t))
            )
            t_base = _time(baseline, s, reps=reps)
            t_comp = _time(comp, s, reps=reps)
            t_fast, (b_fast, r_fast), stats = _time_fast(s, sigma, reps=reps)

            b1, r1 = baseline(s)
            b2, r2 = comp(s)
            match = bool(
                np.array_equal(np.asarray(b1), np.asarray(b2))
                and np.array_equal(np.asarray(b1), np.asarray(b_fast))
                and int(r1) == int(r2) == int(r_fast)
            )
            rows.append({
                "input": f"{kind}.{n}",
                "n": n,
                "sigma": sigma,
                "ours_s": t_fast,
                "baseline_s": t_base,
                "competitor_s": t_comp,
                "speedup": t_comp / t_fast,
                "build_speedup": t_base / t_fast,
                "outputs_match": match,
                "q": stats.q,
                "rounds_executed": stats.rounds_executed,
                "rounds_skipped": stats.rounds_skipped,
                "active_frac": [round(f, 6) for f in stats.active_frac],
                "local_sort": stats.local_sort,
            })
    return rows


def write_json(rows, path):
    payload = {
        "bench": "table2_build",
        "backend": jax.default_backend(),
        "rows": rows,
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + 1 rep (CI build-bench smoke)")
    ap.add_argument("--json", default=DEFAULT_JSON,
                    help="BENCH_build.json output path ('' to skip)")
    args = ap.parse_args(argv)
    sizes = (1 << 10, 1 << 12) if args.smoke else (1 << 14, 1 << 16)
    rows = run(sizes=sizes, reps=1 if args.smoke else 3)
    print("table2,input,ours_s,baseline_s,competitor_s,speedup,"
          "build_speedup,rounds,skipped,outputs_match")
    for r in rows:
        print(
            f"table2,{r['input']},{r['ours_s']:.4f},{r['baseline_s']:.4f},"
            f"{r['competitor_s']:.4f},{r['speedup']:.2f},"
            f"{r['build_speedup']:.2f},{r['rounds_executed']},"
            f"{r['rounds_skipped']},{r['outputs_match']}"
        )
    if args.json:
        print(f"table2,json,{write_json(rows, args.json)}")
    assert all(r["outputs_match"] for r in rows), "BWT outputs diverged"


if __name__ == "__main__":
    main()
