#!/usr/bin/env python
"""Fail on broken *relative* links in the repo's markdown files.

Checks every ``[text](target)`` whose target is not an absolute URL or
in-page anchor: the referenced file/directory must exist relative to the
markdown file.  Used by the CI docs job so README/docs pointers can't rot.

    python scripts/check_md_links.py [root]
"""

from __future__ import annotations

import os
import re
import sys

# [text](target) — target captured up to the first unescaped ')'
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check(root: str) -> list[str]:
    errors = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [
            d for d in dirnames
            if d not in (".git", "__pycache__", ".pytest_cache", "node_modules")
        ]
        for fn in filenames:
            if not fn.endswith(".md"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, encoding="utf-8") as f:
                text = f.read()
            # fenced code blocks are not links
            text = re.sub(r"```.*?```", "", text, flags=re.S)
            for m in LINK_RE.finditer(text):
                target = m.group(1)
                if target.startswith(SKIP_PREFIXES):
                    continue
                target = target.split("#", 1)[0]  # drop section anchors
                if not target:
                    continue
                resolved = os.path.normpath(os.path.join(dirpath, target))
                if not os.path.exists(resolved):
                    rel = os.path.relpath(path, root)
                    errors.append(f"{rel}: broken link -> {m.group(1)}")
    return errors


def main() -> int:
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    errors = check(root)
    for e in errors:
        print(e, file=sys.stderr)
    n_md = sum(
        fn.endswith(".md")
        for _, _, fns in os.walk(root) for fn in fns
    )
    print(f"checked {n_md} markdown files: {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
