"""Schema + smoke-regression checks for ``experiments/BENCH_*.json``.

Two modes:

* ``python scripts/check_bench_json.py`` — validate every committed
  ``experiments/BENCH_*.json`` against the conventions in
  docs/BENCHMARKS.md: top level ``{"bench", "backend", "rows"}``, rows are
  non-empty dicts keyed by ``input``/``scenario``, every ``*_match``
  correctness bit is true, wall-time fields are finite and non-negative,
  and any row carrying both ``speedup`` and ``outputs_match`` (the
  compaction rows) has ``speedup >= 1.0`` — a rebuild-free strategy that
  loses to the rebuild it replaces is a regression, not a baseline.
  A malformed committed artifact fails CI loudly instead of silently
  corrupting the perf trajectory.

* ``... --baseline A.json --candidate B.json [--tol 3]`` — regression-gate
  a fresh smoke run against the committed baseline.  Rows are matched by
  id (``scenario`` or ``input``); for each shared numeric metric,
  lower-is-better fields (``*_ms``, ``*_s``) may grow at most ``tol``x and
  higher-is-better fields (``*qps``, ``*speedup``) may shrink at most
  ``tol``x.  Absolute floors (a few ms / a few qps) keep timer noise on
  near-zero smoke metrics from flaking CI; a genuine 3x regression on a
  metric that matters clears them easily.
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

# absolute slack added on top of the ratio tolerance, per unit suffix —
# sized for cross-machine noise (the committed baseline comes from a dev
# box, the candidate from a CI runner): single-digit-ms smoke latencies
# jitter far more than 3x under a different CPU + background load, while a
# real regression (serialization bug, lost batching) blows past ratio+floor
FLOORS = {"_ms": 50.0, "_s": 0.5, "qps": 150.0, "speedup": 0.2}


def fail(msg: str) -> None:
    print(f"check_bench_json: FAIL: {msg}")
    sys.exit(1)


def row_id(row: dict) -> str | None:
    return row.get("scenario") or row.get("input")


def check_schema(path: str) -> dict:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: unreadable JSON ({e})")
    for key, typ in (("bench", str), ("backend", str), ("rows", list)):
        if not isinstance(payload.get(key), typ):
            fail(f"{path}: missing/invalid top-level {key!r}")
    if not payload["rows"]:
        fail(f"{path}: empty rows")
    for i, row in enumerate(payload["rows"]):
        if not isinstance(row, dict):
            fail(f"{path}: rows[{i}] is not an object")
        if row_id(row) is None:
            fail(f"{path}: rows[{i}] has neither 'scenario' nor 'input'")
        for key, val in row.items():
            if key.endswith("_match") and val is not True:
                fail(f"{path}: rows[{i}].{key} = {val!r} (correctness bit "
                     "must be true)")
            if (key.endswith(("_s", "_ms")) and isinstance(val, (int, float))
                    and (not math.isfinite(val) or val < 0)):
                fail(f"{path}: rows[{i}].{key} = {val!r} (bad wall time)")
        # compaction rows must never lose to the rebuild they replace: a
        # committed speedup < 1.0 means the serving default regressed (the
        # PR-10 0.85x row must stay impossible to reintroduce)
        speedup = row.get("speedup")
        if (isinstance(speedup, (int, float)) and not isinstance(
                speedup, bool) and "outputs_match" in row and speedup < 1.0):
            fail(f"{path}: rows[{i}] ({row_id(row)!r}) speedup = "
                 f"{speedup:.4g} < 1.0 — the measured strategy lost to its "
                 "oracle/baseline")
    return payload


# load-generator knobs, not measurements — a slower candidate machine
# legitimately picks a lower arrival rate, so these must not be gated
KNOB_KEYS = {"target_qps"}


def _direction(key: str) -> str | None:
    """'lower' / 'higher' / None (not a perf metric)."""
    if key in KNOB_KEYS:
        return None
    if key.endswith(("qps", "speedup")):
        return "higher"
    if key.endswith(("_ms", "_s")):
        return "lower"
    return None


def _floor(key: str) -> float:
    for suffix, floor in FLOORS.items():
        if key.endswith(suffix):
            return floor
    return 0.0


def compare(baseline: dict, candidate: dict, tol: float) -> None:
    base_rows = {row_id(r): r for r in baseline["rows"]}
    cand_rows = {row_id(r): r for r in candidate["rows"]}
    shared = sorted(set(base_rows) & set(cand_rows))
    if not shared:
        fail("no shared row ids between baseline and candidate")
    compared = 0
    for rid in shared:
        b, c = base_rows[rid], cand_rows[rid]
        for key, bval in b.items():
            direction = _direction(key)
            cval = c.get(key)
            if (direction is None or not isinstance(bval, (int, float))
                    or not isinstance(cval, (int, float))
                    or isinstance(bval, bool) or isinstance(cval, bool)):
                continue
            compared += 1
            floor = _floor(key)
            if direction == "lower" and cval > bval * tol + floor:
                fail(f"row {rid!r}: {key} regressed {bval:.4g} -> "
                     f"{cval:.4g} (> {tol}x + {floor})")
            if direction == "higher" and cval < bval / tol - floor:
                fail(f"row {rid!r}: {key} regressed {bval:.4g} -> "
                     f"{cval:.4g} (< 1/{tol}x - {floor})")
    if not compared:
        fail("no comparable numeric metrics in shared rows")
    print(f"check_bench_json: OK ({len(shared)} shared rows, "
          f"{compared} metrics within {tol}x)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", help="committed BENCH_*.json")
    ap.add_argument("--candidate", help="fresh (smoke) BENCH_*.json")
    ap.add_argument("--tol", type=float, default=3.0,
                    help="max allowed regression ratio")
    args = ap.parse_args(argv)

    if bool(args.baseline) != bool(args.candidate):
        ap.error("--baseline and --candidate go together")
    if args.baseline:
        # a bench whose baseline (or smoke output) does not exist yet is a
        # legitimate state — e.g. a new benchmark with no committed
        # artifact, or a CI lane that skipped the producing job.  Skip
        # cleanly instead of failing as "unreadable JSON".
        for role, path in (("baseline", args.baseline),
                           ("candidate", args.candidate)):
            if not os.path.exists(path):
                print(f"check_bench_json: SKIP: {role} {path!r} does not "
                      "exist yet (nothing to gate — commit/produce it to "
                      "enable the regression gate)")
                return
        compare(check_schema(args.baseline), check_schema(args.candidate),
                args.tol)
        return

    paths = sorted(glob.glob(os.path.join(EXPERIMENTS, "BENCH_*.json")))
    if not paths:
        fail(f"no BENCH_*.json under {os.path.abspath(EXPERIMENTS)}")
    for path in paths:
        payload = check_schema(path)
        print(f"check_bench_json: OK {os.path.basename(path)} "
              f"({payload['bench']}, {len(payload['rows'])} rows)")


if __name__ == "__main__":
    main()
