"""Version-compat shims for the jax API surface this repo targets.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and its replication-check kwarg was renamed
(``check_rep`` -> ``check_vma``) along the way.  Every call site imports
``shard_map`` from here and uses the modern spelling; this module adapts it
to whatever the installed jax provides.

On versions that only know ``check_rep``, the checker predates per-branch
replication inference and rejects valid programs containing ``lax.cond``
(mismatched replication types), so the shim defaults the check off there —
the modern checker still runs untouched on newer jax.
"""

from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:

    def shard_map(*args, **kwargs):
        kwargs["check_rep"] = kwargs.pop("check_vma", False)
        return _shard_map(*args, **kwargs)


__all__ = ["shard_map"]
