"""Deterministic, resumable batching for LM training.

Stateless sampling: batch ``i`` is a pure function of ``(seed, i)`` — any
worker can (re)compute any batch, restarts are bitwise-exact, and there is
no shuffle state to lose on preemption (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..models.transformer import LABEL_PAD


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    batch_size: int
    seq_len: int
    seed: int = 0


class TokenLoader:
    """Samples fixed-length windows from a token corpus."""

    def __init__(self, tokens: np.ndarray, cfg: LoaderConfig,
                 drop_mask: np.ndarray | None = None):
        self.tokens = np.asarray(tokens, dtype=np.int32)
        self.cfg = cfg
        self.n = len(self.tokens)
        # windows flagged by dedup are never sampled
        self.drop_mask = drop_mask

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step])
        )
        max_start = self.n - cfg.seq_len - 1
        starts = rng.integers(0, max_start, cfg.batch_size)
        if self.drop_mask is not None:
            for attempt in range(8):  # resample dropped windows
                bad = self.drop_mask[starts]
                if not bad.any():
                    break
                starts[bad] = rng.integers(0, max_start, int(bad.sum()))
        idx = starts[:, None] + np.arange(cfg.seq_len + 1)[None, :]
        window = self.tokens[idx]
        return {
            "tokens": window[:, :-1].copy(),
            "labels": window[:, 1:].copy(),
        }

    def batches(self, start_step: int, num: int):
        for s in range(start_step, start_step + num):
            yield s, self.batch(s)


def pad_labels(labels: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    out = labels.copy()
    for i, L in enumerate(lengths):
        out[i, L:] = LABEL_PAD
    return out
