"""Synthetic Pizza&Chili-style corpora + deterministic generation.

The paper validates on PROTEINS / DNA / ENGLISH from Pizza&Chili [11]; this
container is offline, so we generate statistically similar token streams
(same alphabets, newline-separated records, Zipf-ish word distribution for
ENGLISH) with fully deterministic seeding — every worker can regenerate any
slice (DESIGN.md §7, "no shuffle files").
"""

from __future__ import annotations

import numpy as np

from ..core import alphabet as al

NEWLINE = 11  # token id reserved for the record separator inside bio corpora


def dna(n: int, seed: int = 0) -> np.ndarray:
    """Gene-like DNA records: ACGT (ids 1..4) with newline separators."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xD7A]))
    toks = rng.integers(1, 5, n).astype(np.int32)
    # records of ~1k bases
    rec = rng.integers(500, 1500)
    toks[np.arange(rec, n, rec)] = 5  # separator id 5
    return toks


def proteins(n: int, seed: int = 0) -> np.ndarray:
    """Swissprot-like protein records over the 20-letter alphabet."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x9B0]))
    # mildly non-uniform residue frequencies
    freq = rng.dirichlet(np.full(20, 5.0))
    toks = rng.choice(np.arange(1, 21), size=n, p=freq).astype(np.int32)
    rec = rng.integers(200, 600)
    toks[np.arange(rec, n, rec)] = 21
    return toks


def english(n: int, seed: int = 0) -> np.ndarray:
    """Zipf-distributed 'words' over bytes — Gutenberg-ish statistics."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xE16]))
    vocab_words = 2048
    ranks = np.arange(1, vocab_words + 1)
    p = (1.0 / ranks) / np.sum(1.0 / ranks)
    word_lens = rng.integers(2, 9, vocab_words)
    letters = [rng.integers(ord("a"), ord("z") + 1, L).astype(np.uint8)
               for L in word_lens]
    out = np.empty(n + 16, dtype=np.int32)
    i = 0
    # vectorised-ish assembly in chunks
    while i < n:
        words = rng.choice(vocab_words, size=4096, p=p)
        for w in words:
            ltrs = letters[w]
            j = min(len(ltrs), n + 16 - i - 1)
            out[i : i + j] = ltrs[:j].astype(np.int32) + 1
            i += j
            out[i] = ord(" ") + 1
            i += 1
            if i >= n:
                break
    return out[:n]


GENERATORS = {"dna": dna, "proteins": proteins, "english": english}


def corpus(kind: str, n: int, seed: int = 0) -> np.ndarray:
    return GENERATORS[kind](n, seed)


def sigma_for(kind: str) -> int:
    return {"dna": 6, "proteins": 22, "english": 257}[kind]
