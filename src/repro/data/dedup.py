"""BWT/FM-index powered data hygiene for LM training.

This is where the paper's contribution becomes a first-class feature of the
training framework (DESIGN.md §3): the distributed index built by
``core.pipeline`` answers exact-substring queries over the whole corpus, so
the data pipeline can
  * drop exact duplicate windows (train-time dedup), and
  * screen held-out/eval sequences that leak into the corpus (contamination).
"""

from __future__ import annotations

import numpy as np

from ..core.fm_index import PAD
from ..core.pipeline import SequenceIndex, build_index


def build_corpus_index(tokens: np.ndarray, mesh=None, **kw) -> SequenceIndex:
    return build_index(tokens, mesh, **kw)


def duplicate_window_mask(
    index: SequenceIndex, tokens: np.ndarray, window: int,
    stride: int | None = None, threshold: int = 2, batch: int = 256,
) -> np.ndarray:
    """mask[i] = True when the window starting at i occurs >= ``threshold``
    times in the indexed corpus (an exact duplicate somewhere else)."""
    stride = stride or window
    n = len(tokens)
    starts = np.arange(0, n - window, stride)
    mask = np.zeros(n, dtype=bool)
    for lo in range(0, len(starts), batch):
        chunk = starts[lo : lo + batch]
        pats = np.stack([tokens[s : s + window] for s in chunk]).astype(np.int32)
        counts = np.asarray(index.count(pats))
        for s, c in zip(chunk, counts):
            if c >= threshold:
                mask[s : s + stride] = True
    return mask


def contamination_report(
    index: SequenceIndex, eval_sequences: list[np.ndarray], probe_len: int = 32,
) -> dict:
    """For each eval sequence, count corpus hits of its probes."""
    probes = []
    owners = []
    for i, seq in enumerate(eval_sequences):
        for s in range(0, max(1, len(seq) - probe_len + 1), probe_len):
            probes.append(seq[s : s + probe_len])
            owners.append(i)
    L = max(len(p) for p in probes)
    pats = np.full((len(probes), L), PAD, np.int32)
    for j, p in enumerate(probes):
        pats[j, : len(p)] = p
    counts = np.asarray(index.count(pats))
    hits = {}
    for i, c in zip(owners, counts):
        hits[i] = hits.get(i, 0) + int(c > 0)
    return {
        "contaminated": sorted(k for k, v in hits.items() if v > 0),
        "probe_hits": hits,
    }
