"""Logical-axis sharding rules (MaxText-style) for params and activations.

Every parameter is declared with logical axis names (models/common.ParamSpec);
this module maps logical axes -> mesh axes with divisibility fallbacks, so one
rule set serves every architecture and mesh.

Mesh layouts (launch/mesh.py):
    single pod : (data=16, model=16)            axes ("data", "model")
    multi pod  : (pod=2, data=16, model=16)     axes ("pod", "data", "model")
    index build: (parts=N,)                     axes ("parts",)
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (first that divides wins; None if none)
TRAIN_RULES: dict[str, tuple[str, ...]] = {
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "inner": ("model",),          # SSM / RG-LRU channel dim
    "fsdp": ("data",),            # ZeRO-3: shard weight d_model dims
    "expert_ff": ("pod",),        # expert hidden dim: extra FSDP over pods
    "q_lora": ("data",),
    "kv_lora": ("data",),
    "head_dim": (),
    "state": (),
    "conv": (),
    "layers": (),                 # scan axis stays replicated
    "batch": ("pod", "data"),
    "seq": (),
    "act_model": ("model",),      # activation head/mlp dims
}

# decode: FSDP off (weights must be resident), batch over (pod, data)
DECODE_RULES = dict(TRAIN_RULES, fsdp=())


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Mesh + the axis-name vocabulary the model code uses."""

    mesh: Mesh
    rules: Mapping[str, tuple[str, ...]]

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.rules.get("batch", ()) if a in self.mesh.shape)

    @property
    def model_axis(self) -> str | None:
        return "model" if "model" in self.mesh.shape else None

    def axis_size(self, names: Sequence[str]) -> int:
        size = 1
        for n in names:
            size *= self.mesh.shape.get(n, 1)
        return size

    def spec_for(self, logical_axes: Sequence[str | None],
                 dim_sizes: Sequence[int]) -> P:
        """PartitionSpec for one array, with divisibility fallback: a logical
        axis maps to its preferred mesh axes only if the dim divides evenly
        and the mesh axis is not already taken by an earlier dim."""
        used: set[str] = set()
        parts = []
        for ax, size in zip(logical_axes, dim_sizes):
            choice: tuple[str, ...] | None = None
            if ax is not None:
                prefs = tuple(a for a in self.rules.get(ax, ()) if a in self.mesh.shape)
                # try the full tuple first (e.g. batch -> (pod, data)), then
                # single axes
                candidates = [prefs] + [(a,) for a in prefs]
                for cand in candidates:
                    if not cand or any(a in used for a in cand):
                        continue
                    total = self.axis_size(cand)
                    if total > 1 and size % total == 0:
                        choice = cand
                        break
            if choice:
                used.update(choice)
                parts.append(choice if len(choice) > 1 else choice[0])
            else:
                parts.append(None)
        return P(*parts)

    def sharding_for(self, logical_axes, dim_sizes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(logical_axes, dim_sizes))


def constrain(x: jax.Array, ctx: MeshContext, logical_axes) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op off-mesh dims)."""
    spec = ctx.spec_for(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def single_device_context(rules=TRAIN_RULES) -> MeshContext:
    """1-device mesh with the production axis names (smoke tests)."""
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    return MeshContext(mesh, rules)
