"""Deterministic fault injection: named failpoints on the durability and
serving paths, driven by an explicit (replayable) schedule.

The production code calls :func:`fault_point` at every place a crash or a
torn IO operation is interesting; when no schedule is armed the call is a
no-op costing one global read.  Tests (and ``launch/serve.py
--fault-schedule`` demos) arm a :class:`FaultSchedule` that says *the k-th
hit of failpoint NAME raises* — so a crash can be injected at **every**
site, one at a time, and replayed exactly: schedules are pure data, hit
counters are deterministic for a deterministic workload, and a
record-only schedule (no triggers) discovers how many times each failpoint
fires so a sweep can cover all of them.

Failpoint catalog (every name the tree currently hits):

=================  ==========================================================
``io.write``       before writing a durable artifact file (checkpoint
                   arrays/manifest, segment tokens, generation manifest)
``io.fsync``       before fsyncing a file that must be durable pre-commit
``io.rename``      before the atomic rename that publishes an artifact or
                   commits a generation
``merge.mid``      mid BWT-merge, after the interleave walk and before the
                   merged index exists (``core.bwt_merge`` — hit by both
                   the pairwise and the k-way path)
``merge.kway``     mid k-way merge only: after the chained multi-walker
                   walk, before the one-pass splice (``bwt_merge.merge_kway``)
``worker.flush``   inside the serving frontend's flush worker, outside its
                   recovery guards — simulates the worker thread dying
``restore.checksum`` while verifying an artifact checksum on restore — a
                   hit simulates the checksum coming back wrong (the reader
                   treats it as corruption, it does not propagate)
=================  ==========================================================

Scheduling grammar (``FaultSchedule.parse`` / ``--fault-schedule``):
``"io.write:2"`` fires on the third hit of ``io.write``;
``"io.write:0,io.rename:1"`` arms several independent triggers.  Each
trigger fires once (crash-then-recover semantics); hit counting continues
so a later trigger index still lines up.
"""

from __future__ import annotations

import contextlib
import os
import threading

FAILPOINTS = (
    "io.write",
    "io.fsync",
    "io.rename",
    "merge.mid",
    "merge.kway",
    "worker.flush",
    "restore.checksum",
)

ENV_VAR = "REPRO_FAULT_SCHEDULE"


class InjectedFault(RuntimeError):
    """Raised by an armed failpoint — the simulated crash."""


class FaultSchedule:
    """Which (failpoint, hit-index) pairs fire, plus deterministic counters.

    ``hits`` counts every time each failpoint was reached (fired or not);
    ``fired`` lists the (name, hit_index) pairs that actually raised.  A
    schedule with no triggers is a pure recorder — run the workload once
    under it to learn the hit counts, then sweep one trigger per hit.
    Thread-safe: the serving frontend's worker thread hits failpoints
    concurrently with the test thread.
    """

    def __init__(self, triggers=()):
        self._triggers: dict[str, set[int]] = {}
        for t in triggers:
            if isinstance(t, str):
                name, _, idx = t.partition(":")
                t = (name.strip(), int(idx))
            name, idx = t
            if name not in FAILPOINTS:
                raise ValueError(
                    f"unknown failpoint {name!r} (known: {FAILPOINTS})"
                )
            self._triggers.setdefault(name, set()).add(int(idx))
        self.hits: dict[str, int] = {}
        self.fired: list[tuple[str, int]] = []
        self._lock = threading.Lock()

    @classmethod
    def parse(cls, spec: str) -> "FaultSchedule":
        """``"name:k[,name:k...]"`` -> schedule (empty spec = recorder)."""
        parts = [p for p in (spec or "").split(",") if p.strip()]
        return cls(parts)

    def should_fire(self, name: str) -> bool:
        """Count one hit of ``name``; True when an armed trigger matches.
        Each trigger fires at most once."""
        with self._lock:
            k = self.hits.get(name, 0)
            self.hits[name] = k + 1
            armed = self._triggers.get(name)
            if armed and k in armed:
                armed.discard(k)
                self.fired.append((name, k))
                return True
            return False

    def report(self) -> dict:
        """JSON-able summary (hit counts + what fired) for demo output."""
        with self._lock:
            return {"hits": dict(self.hits), "fired": list(self.fired)}


_active: FaultSchedule | None = None
_arm_lock = threading.Lock()


def arm(schedule: FaultSchedule | None) -> FaultSchedule | None:
    """Persistently install ``schedule`` (None disarms); returns it."""
    global _active
    with _arm_lock:
        _active = schedule
    return schedule


def active() -> FaultSchedule | None:
    return _active


@contextlib.contextmanager
def inject(schedule: FaultSchedule):
    """Arm ``schedule`` for the duration of the block (restores the
    previous schedule on exit, even on the injected crash itself)."""
    global _active
    with _arm_lock:
        prev, _active = _active, schedule
    try:
        yield schedule
    finally:
        with _arm_lock:
            _active = prev


def fault_point(name: str) -> None:
    """Declare a failpoint.  No-op unless an armed schedule fires here."""
    s = _active
    if s is not None and s.should_fire(name):
        raise InjectedFault(f"injected fault at {name!r} "
                            f"(hit {s.hits[name] - 1})")


def checksum_fault(name: str = "restore.checksum") -> bool:
    """Failpoint variant for verification sites: True = pretend the check
    failed (simulated torn read), instead of raising."""
    s = _active
    return s is not None and s.should_fire(name)


def arm_from_env() -> FaultSchedule | None:
    """Arm from ``REPRO_FAULT_SCHEDULE`` (subprocess scenarios under CI);
    returns the armed schedule or None when the variable is unset/empty."""
    spec = os.environ.get(ENV_VAR, "")
    if not spec.strip():
        return None
    return arm(FaultSchedule.parse(spec))
