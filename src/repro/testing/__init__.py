"""Test-support utilities shipped with the library (deterministic fault
injection for crash-recovery testing; see ``repro.testing.faultinject``)."""
