"""Async serving frontend: admission-controlled request queue in front of
``FMQueryServer``, with max-batch/max-wait coalescing and per-bucket
latency SLO accounting.

``FMQueryServer.flush`` is a synchronous call: whoever holds the thread
pays for the whole batch, there is no backpressure, and a traffic spike
just grows the Python queue until memory runs out.  This module is the
serving layer the paper's cloud story implies (§1: many users querying one
distributed index):

* ``submit`` is non-blocking and thread-safe; it returns a
  ``concurrent.futures.Future`` resolving to an ``FMQueryResult``.
* **Admission control**: the queue is bounded (``max_queue``); submits
  beyond the bound resolve immediately to a ``Rejected`` result — overload
  degrades by shedding load, never by OOMing or stalling admitted work.
* A background worker coalesces admitted requests into flushes: it fires
  as soon as ``max_batch`` requests are waiting OR the oldest request has
  waited ``max_wait_ms`` — the standard batching latency/throughput knob
  (same playbook as LM decode micro-batching).
* **Per-bucket latency accounting**: every completed request records its
  enqueue-to-resolve latency under its jit bucket (kind + padded length);
  ``metrics()`` exports p50/p99 per bucket plus shed/throughput counters,
  checked against per-kind p99 SLO targets.
* **Live appends**: when the served index is a ``SegmentedIndex``,
  ``append`` enqueues an index-growth control op.  The flush worker (the
  single thread owning all index dispatches, so growth never races a
  query) applies it *between* flushes and then runs the background
  compaction policy (``maybe_compact`` — rebuild-free BWT-merge by
  default), so steady-state serving absorbs appends without ever paying a
  full O(corpus) re-sort.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

from .engine import FMQueryServer


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Admission-control shed marker: the request was NOT answered.

    Returned (inside the future) instead of ``FMQueryResult`` when the
    queue is at ``max_queue`` depth.  Clients retry with backoff or drop.
    """

    kind: str                   # "count" | "locate" — mirrors the request
    reason: str = "queue_full"


@dataclasses.dataclass
class _BucketStats:
    """Latency accounting for one jit bucket (kind, padded length)."""

    slo_p99_ms: float | None
    window: dataclasses.InitVar[int] = 4096
    completed: int = 0
    violations: int = 0         # individual latencies over the SLO target
    latencies_ms: deque = None

    def __post_init__(self, window):
        self.latencies_ms = deque(maxlen=window)

    def record(self, lat_ms: float) -> None:
        self.completed += 1
        self.latencies_ms.append(lat_ms)
        if self.slo_p99_ms is not None and lat_ms > self.slo_p99_ms:
            self.violations += 1

    def summary(self) -> dict:
        lat = np.asarray(self.latencies_ms, np.float64)
        p50 = float(np.percentile(lat, 50)) if lat.size else 0.0
        p99 = float(np.percentile(lat, 99)) if lat.size else 0.0
        out = {
            "completed": self.completed,
            "p50_ms": p50,
            "p99_ms": p99,
            "slo_p99_ms": self.slo_p99_ms,
            "slo_ok": (p99 <= self.slo_p99_ms
                       if self.slo_p99_ms is not None and lat.size else None),
            "violations": self.violations,
        }
        return out


class AsyncQueryFrontend:
    """Admission-controlled async frontend over an ``FMQueryServer``.

        server = FMQueryServer(index)
        with AsyncQueryFrontend(server, max_queue=4096) as fe:
            fut = fe.submit(pattern, "count")
            ...
            res = fut.result()          # FMQueryResult | Rejected
            print(fe.metrics())

    One background worker owns all index dispatches (jax calls never race);
    producers only touch the bounded queue under a lock.  ``stop()`` (or
    leaving the ``with`` block) drains admitted requests before returning —
    an admitted future always resolves.
    """

    def __init__(self, server: FMQueryServer, *, max_queue: int = 8192,
                 max_wait_ms: float = 2.0, max_batch: int | None = None,
                 slo_p99_ms: dict[str, float] | None = None,
                 window: int = 4096, autostart: bool = True):
        self.server = server
        self.max_queue = max_queue
        self.max_wait_s = max_wait_ms / 1e3
        self.max_batch = server.max_batch if max_batch is None else max_batch
        self.slo_p99_ms = dict(slo_p99_ms or {})  # per kind: {"count": ms}
        self.window = window
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # (t_enqueue, pattern, kind, k, future) — append under the lock only
        self._pending: deque = deque()
        # (tokens, future) index-growth ops, drained before each flush
        self._control: deque = deque()
        self._stop = False
        self._thread: threading.Thread | None = None
        self._t_start = time.perf_counter()
        self.admitted = 0
        self.rejected = 0
        self.completed = 0
        self.flushes = 0
        self.appends = 0
        self.compactions = 0
        self._buckets: dict[str, _BucketStats] = {}
        if autostart:
            self.start()

    @classmethod
    def from_config(cls, server: FMQueryServer, cfg,
                    **kw) -> "AsyncQueryFrontend":
        """Build from a BWTIndexConfig's frontend knobs."""
        kw.setdefault("max_queue", cfg.serve_queue_depth)
        kw.setdefault("max_wait_ms", cfg.serve_max_wait_ms)
        kw.setdefault("slo_p99_ms", {"count": cfg.serve_slo_p99_ms,
                                     "locate": cfg.serve_slo_p99_ms_locate})
        return cls(server, **kw)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        """Start the flush worker (idempotent)."""
        with self._lock:
            if self._thread is not None:
                return
            self._stop = False
            self._thread = threading.Thread(
                target=self._run, name="fm-frontend-flush", daemon=True
            )
            self._thread.start()

    def stop(self) -> None:
        """Drain admitted requests, then stop the worker.  Safe to call
        with the worker never started (pending requests are flushed
        inline so admitted futures still resolve)."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join()
        else:
            self._drain_inline()

    def __enter__(self) -> "AsyncQueryFrontend":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- producer side -------------------------------------------------------

    def submit(self, pattern, kind: str = "count",
               k: int | None = None) -> Future:
        """Enqueue one query; never blocks on the index.

        Returns a future resolving to ``FMQueryResult`` (admitted) or
        ``Rejected`` (queue at ``max_queue`` — already resolved on return).
        ``pattern``/``kind``/``k`` as in ``FMQueryServer.submit``."""
        if kind not in ("count", "locate"):
            raise ValueError(f"unknown query kind {kind!r}")
        fut: Future = Future()
        pat = np.asarray(pattern, np.int32)
        with self._cond:
            if self._stop:
                raise RuntimeError("frontend is stopped")
            if len(self._pending) >= self.max_queue:
                self.rejected += 1
                fut.set_result(Rejected(kind))
                return fut
            self.admitted += 1
            self._pending.append((time.perf_counter(), pat, kind, k, fut))
            self._cond.notify()
        return fut

    def append(self, tokens) -> Future:
        """Grow the served ``SegmentedIndex`` without stopping the frontend.

        Enqueues an index-growth control op; the flush worker applies it
        between flushes (appends a segment, then runs the background
        compaction policy — ``SegmentedIndex.maybe_compact``, rebuild-free
        BWT merge by default).  Returns a future resolving to a summary
        dict {"appended", "merges", "segments", "total_tokens"}.  Queries
        admitted after the future resolves see the new text.  Control ops
        are never shed (they carry corpus data, not load).
        """
        if not hasattr(self.server.index, "append"):
            raise TypeError(
                f"served index {type(self.server.index).__name__} does not "
                "support append (serve a SegmentedIndex)"
            )
        fut: Future = Future()
        toks = np.asarray(tokens, np.int32)
        with self._cond:
            if self._stop:
                raise RuntimeError("frontend is stopped")
            self._control.append((toks, fut))
            self._cond.notify()
        return fut

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._pending)

    # -- worker side ---------------------------------------------------------

    def _take_work(self):
        """Block until there is work: ("ctrl", ops) for pending index
        growth (always drained before the next flush), ("batch", requests)
        once max-batch/max-wait coalescing trips, None = stopped and
        drained."""
        with self._cond:
            while (not self._pending and not self._control
                   and not self._stop):
                self._cond.wait()
            if self._control:
                ctrl = list(self._control)
                self._control.clear()
                return "ctrl", ctrl
            if not self._pending:
                return None                   # stopping, nothing left
            while (len(self._pending) < self.max_batch and not self._stop
                   and not self._control):    # appends cut coalescing short
                oldest = self._pending[0][0]
                remaining = oldest + self.max_wait_s - time.perf_counter()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
            batch = list(self._pending)
            self._pending.clear()
            return "batch", batch

    def _run(self) -> None:
        while True:
            work = self._take_work()
            if work is None:
                return
            kind, items = work
            if kind == "ctrl":
                self._apply_controls(items)
            else:
                self._flush_batch(items)

    def _apply_controls(self, ctrl: list) -> None:
        """Apply index-growth ops on the worker thread (the only thread
        dispatching into the index, so growth cannot race a flush)."""
        for toks, fut in ctrl:
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                index = self.server.index
                seg = index.append(toks)
                merges = index.maybe_compact()
                out = {
                    "appended": int(seg.n_tokens), "merges": int(merges),
                    "segments": len(index.segments),
                    "total_tokens": int(index.total_tokens),
                }
            except Exception as e:  # noqa: BLE001 — worker must survive
                fut.set_exception(e)
                continue
            with self._lock:
                self.appends += 1
                self.compactions += merges
            fut.set_result(out)

    def _drain_inline(self) -> None:
        with self._cond:
            ctrl = list(self._control)
            self._control.clear()
            batch = list(self._pending)
            self._pending.clear()
        if ctrl:
            self._apply_controls(ctrl)
        if batch:
            self._flush_batch(batch)

    def _flush_batch(self, batch: list) -> None:
        # claim every future before dispatch: a client cancel() between
        # admission and flush drops the request here; once claimed,
        # set_result can no longer race a cancel and kill the worker
        batch = [e for e in batch if e[4].set_running_or_notify_cancel()]
        if not batch:
            return
        try:
            # the whole dispatch is guarded: the single worker thread must
            # survive ANY failure (bad pattern, a foreign flush of the
            # shared server stealing tickets, ...) — an admitted future
            # must resolve, if only to an exception
            tickets = [
                self.server.submit(pat, kind, k=k)
                for (_, pat, kind, k, _) in batch
            ]
            results = self.server.flush()
            outs = [results[t] for t in tickets]
        except Exception as e:  # noqa: BLE001 — the worker must survive
            for (_, _, _, _, fut) in batch:
                if not fut.done():
                    fut.set_exception(e)
            return
        t_done = time.perf_counter()
        with self._lock:
            self.flushes += 1
            self.completed += len(batch)
            for (t0, pat, kind, _, _) in batch:
                self._bucket(kind, len(pat)).record((t_done - t0) * 1e3)
        for out, (_, _, _, _, fut) in zip(outs, batch):
            fut.set_result(out)

    def _bucket(self, kind: str, m: int) -> _BucketStats:
        key = f"{kind}/{self.server._bucket_len(m)}"
        if key not in self._buckets:
            self._buckets[key] = _BucketStats(
                self.slo_p99_ms.get(kind), self.window
            )
        return self._buckets[key]

    # -- observability -------------------------------------------------------

    def metrics(self) -> dict:
        """Serving metrics snapshot (JSON-able).

        ``buckets`` maps "kind/padded-length" (one per jit program the
        server compiled) to {completed, p50_ms, p99_ms, slo_p99_ms, slo_ok,
        violations} over the last ``window`` completions; top level carries
        admitted/rejected/completed counters, the shed fraction, sustained
        qps since start, and the live queue depth."""
        with self._lock:
            offered = self.admitted + self.rejected
            elapsed = time.perf_counter() - self._t_start
            return {
                "admitted": self.admitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "flushes": self.flushes,
                "appends": self.appends,
                "compactions": self.compactions,
                "shed_frac": self.rejected / offered if offered else 0.0,
                "qps": self.completed / elapsed if elapsed > 0 else 0.0,
                "queue_depth": len(self._pending),
                "max_queue": self.max_queue,
                "buckets": {
                    key: b.summary()
                    for key, b in sorted(self._buckets.items())
                },
            }
