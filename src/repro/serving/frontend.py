"""Async serving frontend: admission-controlled request queue in front of
``FMQueryServer``, with max-batch/max-wait coalescing, per-bucket latency
SLO accounting, and a self-healing fault model.

``FMQueryServer.flush`` is a synchronous call: whoever holds the thread
pays for the whole batch, there is no backpressure, and a traffic spike
just grows the Python queue until memory runs out.  This module is the
serving layer the paper's cloud story implies (§1: many users querying one
distributed index):

* ``submit`` is non-blocking and thread-safe; it returns a
  ``concurrent.futures.Future`` resolving to an ``FMQueryResult``.
* **Admission control**: the queue is bounded (``max_queue``); submits
  beyond the bound resolve immediately to a ``Rejected`` result — overload
  degrades by shedding load, never by OOMing or stalling admitted work.
* **Deadlines**: ``submit(..., deadline_ms=...)`` bounds how long the
  caller will wait — a request whose deadline passes before its flush
  dispatches resolves to ``DeadlineExceeded`` instead of waiting forever.
* A background worker coalesces admitted requests into flushes: it fires
  as soon as ``max_batch`` requests are waiting OR the oldest request has
  waited ``max_wait_ms`` — the standard batching latency/throughput knob
  (same playbook as LM decode micro-batching).
* **Per-bucket latency accounting**: every completed request records its
  enqueue-to-resolve latency under its jit bucket (kind + padded length);
  ``metrics()`` exports p50/p99 per bucket plus shed/throughput counters,
  checked against per-kind p99 SLO targets.
* **Live appends**: when the served index is a ``SegmentedIndex``,
  ``append`` enqueues an index-growth control op.  The flush worker (the
  single thread owning all index dispatches, so growth never races a
  query) applies it *between* flushes and then runs the background
  compaction policy (``maybe_compact`` — rebuild-free BWT-merge by
  default), so steady-state serving absorbs appends without ever paying a
  full O(corpus) re-sort.

Fault model (the robustness substrate the lifecycle makes inevitable):

* **Worker watchdog** — if the flush worker thread dies (a bug, an
  injected ``worker.flush`` fault), the dying thread's supervisor fails
  ONLY the in-flight work's futures (with the crash exception), spawns a
  replacement worker, and the rest of the queue keeps serving.
  ``metrics()["worker_restarts"]`` counts the restarts.
* **Growth-op retry** — transient append/compaction failures retry with
  capped exponential backoff (``growth_retries`` / ``growth_backoff_ms``).
  Deterministic input errors (``ValueError``/``TypeError``) fail fast.
* **Poison-op quarantine** — a compaction that exhausts its retries is
  quarantined: the pre-compact generation keeps serving, later appends
  skip compaction until ``resume_compaction()``, and
  ``metrics()["quarantined_segments"]`` / ``["degraded"]`` surface it.
* ``stop()`` (alias ``close()``) always resolves every admitted future:
  the worker drains, and anything it never reached — including work
  stranded by a crash during shutdown — is drained inline.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError

import numpy as np

from ..testing.faultinject import fault_point
from .engine import FMQueryServer


@dataclasses.dataclass(frozen=True)
class Rejected:
    """Admission-control shed marker: the request was NOT answered.

    Returned (inside the future) instead of ``FMQueryResult`` when the
    queue is at ``max_queue`` depth.  Clients retry with backoff or drop.
    """

    kind: str                   # "count" | "locate" — mirrors the request
    reason: str = "queue_full"


@dataclasses.dataclass(frozen=True)
class DeadlineExceeded:
    """The request was admitted but its deadline passed before its flush
    dispatched — resolved instead of leaving the caller waiting forever."""

    kind: str                   # "count" | "locate" — mirrors the request
    reason: str = "deadline"


@dataclasses.dataclass(frozen=True)
class Shutdown:
    """The frontend stopped before this admitted request could dispatch
    and the shutdown drain could not answer it."""

    kind: str
    reason: str = "shutdown"


@dataclasses.dataclass
class _BucketStats:
    """Latency accounting for one jit bucket (kind, padded length)."""

    slo_p99_ms: float | None
    window: dataclasses.InitVar[int] = 4096
    completed: int = 0
    violations: int = 0         # individual latencies over the SLO target
    latencies_ms: deque = None

    def __post_init__(self, window):
        self.latencies_ms = deque(maxlen=window)

    def record(self, lat_ms: float) -> None:
        self.completed += 1
        self.latencies_ms.append(lat_ms)
        if self.slo_p99_ms is not None and lat_ms > self.slo_p99_ms:
            self.violations += 1

    def summary(self) -> dict:
        lat = np.asarray(self.latencies_ms, np.float64)
        p50 = float(np.percentile(lat, 50)) if lat.size else 0.0
        p99 = float(np.percentile(lat, 99)) if lat.size else 0.0
        out = {
            "completed": self.completed,
            "p50_ms": p50,
            "p99_ms": p99,
            "slo_p99_ms": self.slo_p99_ms,
            "slo_ok": (p99 <= self.slo_p99_ms
                       if self.slo_p99_ms is not None and lat.size else None),
            "violations": self.violations,
        }
        return out


# queue entry: (t_enqueue, pattern, kind, k, future, abs_deadline | None)
_FUT = 4
_DEADLINE = 5


class AsyncQueryFrontend:
    """Admission-controlled, self-healing async frontend over an
    ``FMQueryServer``.

        server = FMQueryServer(index)
        with AsyncQueryFrontend(server, max_queue=4096) as fe:
            fut = fe.submit(pattern, "count", deadline_ms=250)
            ...
            res = fut.result()   # FMQueryResult | Rejected | DeadlineExceeded
            print(fe.metrics())

    One background worker owns all index dispatches (jax calls never race);
    producers only touch the bounded queue under a lock.  A supervisor
    restarts the worker if it crashes, failing only the crashed flush's
    futures.  ``stop()``/``close()`` (or leaving the ``with`` block)
    resolves every admitted future before returning.
    """

    def __init__(self, server: FMQueryServer, *, max_queue: int = 8192,
                 max_wait_ms: float = 2.0, max_batch: int | None = None,
                 slo_p99_ms: dict[str, float] | None = None,
                 window: int = 4096, autostart: bool = True,
                 growth_retries: int = 3, growth_backoff_ms: float = 5.0,
                 growth_backoff_cap_ms: float = 80.0):
        self.server = server
        self.max_queue = max_queue
        self.max_wait_s = max_wait_ms / 1e3
        self.max_batch = server.max_batch if max_batch is None else max_batch
        self.slo_p99_ms = dict(slo_p99_ms or {})  # per kind: {"count": ms}
        self.window = window
        self.growth_retries = growth_retries
        self.growth_backoff_ms = growth_backoff_ms
        self.growth_backoff_cap_ms = growth_backoff_cap_ms
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        # entries appended under the lock only; layout per _FUT/_DEADLINE
        self._pending: deque = deque()
        # (tokens, future) index-growth ops, drained before each flush
        self._control: deque = deque()
        self._stop = False
        self._thread: threading.Thread | None = None
        self._inflight = None       # work the worker is dispatching now
        self._t_start = time.perf_counter()
        self.admitted = 0
        self.rejected = 0
        self.completed = 0
        self.flushes = 0
        self.appends = 0
        self.compactions = 0
        # fault counters (exported by metrics())
        self.worker_restarts = 0
        self.retries = 0
        self.quarantined_segments = 0
        self.deadline_exceeded = 0
        self._compaction_quarantined = False
        self._buckets: dict[str, _BucketStats] = {}
        if autostart:
            self.start()

    @classmethod
    def from_config(cls, server: FMQueryServer, cfg,
                    **kw) -> "AsyncQueryFrontend":
        """Build from a BWTIndexConfig's frontend knobs."""
        kw.setdefault("max_queue", cfg.serve_queue_depth)
        kw.setdefault("max_wait_ms", cfg.serve_max_wait_ms)
        kw.setdefault("slo_p99_ms", {"count": cfg.serve_slo_p99_ms,
                                     "locate": cfg.serve_slo_p99_ms_locate})
        kw.setdefault("growth_retries", cfg.serve_growth_retries)
        kw.setdefault("growth_backoff_ms", cfg.serve_growth_backoff_ms)
        return cls(server, **kw)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        """Start the flush worker (idempotent)."""
        with self._lock:
            if self._thread is not None:
                return
            self._stop = False
            self._thread = self._spawn_worker()

    def _spawn_worker(self) -> threading.Thread:
        t = threading.Thread(
            target=self._worker_main, name="fm-frontend-flush", daemon=True
        )
        t.start()
        return t

    def stop(self) -> None:
        """Resolve every admitted future, then stop the worker.

        The worker drains the queue; anything it never reached — never
        started, crashed mid-shutdown, or enqueued in a race with stop —
        is drained inline, so an admitted future can never hang across a
        close (``tests/test_serve_frontend.py`` submit-then-close)."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join()
        self._drain_inline()

    #: ``close()`` is the conventional name; identical semantics.
    close = stop

    def __enter__(self) -> "AsyncQueryFrontend":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- producer side -------------------------------------------------------

    def submit(self, pattern, kind: str = "count", k: int | None = None,
               deadline_ms: float | None = None) -> Future:
        """Enqueue one query; never blocks on the index.

        Returns a future resolving to ``FMQueryResult`` (admitted),
        ``Rejected`` (queue at ``max_queue`` — already resolved on
        return), or ``DeadlineExceeded`` (admitted, but ``deadline_ms``
        elapsed before its flush dispatched).  ``pattern``/``kind``/``k``
        as in ``FMQueryServer.submit``."""
        if kind not in ("count", "locate"):
            raise ValueError(f"unknown query kind {kind!r}")
        if deadline_ms is not None and deadline_ms < 0:
            raise ValueError(f"negative deadline_ms {deadline_ms}")
        fut: Future = Future()
        pat = np.asarray(pattern, np.int32)
        t0 = time.perf_counter()
        deadline = None if deadline_ms is None else t0 + deadline_ms / 1e3
        with self._cond:
            if self._stop:
                raise RuntimeError("frontend is stopped")
            if len(self._pending) >= self.max_queue:
                self.rejected += 1
                fut.set_result(Rejected(kind))
                return fut
            self.admitted += 1
            self._pending.append((t0, pat, kind, k, fut, deadline))
            self._cond.notify()
        return fut

    def append(self, tokens) -> Future:
        """Grow the served ``SegmentedIndex`` without stopping the frontend.

        Enqueues an index-growth control op; the flush worker applies it
        between flushes (appends a segment, then runs the background
        compaction policy — ``SegmentedIndex.maybe_compact``, rebuild-free
        BWT merge by default).  Transient failures retry with capped
        exponential backoff; a compaction that keeps failing is
        quarantined (the pre-compact generation keeps serving).  Returns a
        future resolving to a summary dict {"appended", "merges",
        "segments", "total_tokens", "compaction_quarantined"}.  Queries
        admitted after the future resolves see the new text.  Control ops
        are never shed (they carry corpus data, not load).
        """
        if not hasattr(self.server.index, "append"):
            raise TypeError(
                f"served index {type(self.server.index).__name__} does not "
                "support append (serve a SegmentedIndex)"
            )
        fut: Future = Future()
        toks = np.asarray(tokens, np.int32)
        with self._cond:
            if self._stop:
                raise RuntimeError("frontend is stopped")
            self._control.append((toks, fut))
            self._cond.notify()
        return fut

    def resume_compaction(self) -> None:
        """Lift a poison-op quarantine: later appends run the background
        compaction policy again (e.g. after the faulty input or disk
        condition was repaired)."""
        with self._lock:
            self._compaction_quarantined = False

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return len(self._pending)

    # -- worker side ---------------------------------------------------------

    def _take_work(self):
        """Block until there is work: ("ctrl", ops) for pending index
        growth (always drained before the next flush), ("batch", requests)
        once max-batch/max-wait coalescing trips, None = stopped and
        drained."""
        with self._cond:
            while (not self._pending and not self._control
                   and not self._stop):
                self._cond.wait()
            if self._control:
                ctrl = list(self._control)
                self._control.clear()
                return "ctrl", ctrl
            if not self._pending:
                return None                   # stopping, nothing left
            while (len(self._pending) < self.max_batch and not self._stop
                   and not self._control):    # appends cut coalescing short
                oldest = self._pending[0][0]
                remaining = oldest + self.max_wait_s - time.perf_counter()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
            batch = list(self._pending)
            self._pending.clear()
            return "batch", batch

    def _worker_main(self) -> None:
        """The worker's supervisor: runs the flush loop; on a crash (an
        exception escaping the loop's per-work guards) fails ONLY the
        in-flight work's futures, then spawns a replacement worker —
        queued-but-undispatched requests survive the crash untouched."""
        try:
            self._run()
        except BaseException as e:  # noqa: BLE001 — the watchdog path
            inflight, self._inflight = self._inflight, None
            if inflight is not None:
                futs = [(item[1] if inflight[0] == "ctrl" else item[_FUT])
                        for item in inflight[1]]
                for fut in futs:
                    try:
                        if not fut.done():
                            fut.set_exception(e)
                    except InvalidStateError:
                        pass  # lost a race with a client cancel()
            with self._cond:
                self.worker_restarts += 1
                if not self._stop and self._thread is \
                        threading.current_thread():
                    self._thread = self._spawn_worker()

    def _run(self) -> None:
        while True:
            work = self._take_work()
            if work is None:
                return
            self._inflight = work
            kind, items = work
            if kind == "ctrl":
                self._apply_controls(items)
            else:
                self._flush_batch(items)
            self._inflight = None

    def _with_retries(self, fn):
        """Run a growth op, retrying transient failures with capped
        exponential backoff.  Deterministic input errors (ValueError /
        TypeError) are not transient and fail immediately."""
        delay = self.growth_backoff_ms / 1e3
        attempt = 0
        while True:
            try:
                return fn()
            except (ValueError, TypeError):
                raise
            except Exception:
                if attempt >= self.growth_retries:
                    raise
                attempt += 1
                with self._lock:
                    self.retries += 1
                time.sleep(delay)
                delay = min(delay * 2, self.growth_backoff_cap_ms / 1e3)

    def _apply_controls(self, ctrl: list) -> None:
        """Apply index-growth ops on the worker thread (the only thread
        dispatching into the index, so growth cannot race a flush)."""
        for toks, fut in ctrl:
            if not fut.set_running_or_notify_cancel():
                continue
            index = self.server.index
            try:
                seg = self._with_retries(lambda: index.append(toks))
            except Exception as e:  # noqa: BLE001 — worker must survive
                fut.set_exception(e)
                continue
            # compaction failure must not lose the append: it is retried
            # independently, and a poison op quarantines — the pre-compact
            # generation keeps serving and later appends skip compaction
            merges = 0
            compact_error = None
            if not self._compaction_quarantined:
                try:
                    merges = self._with_retries(index.maybe_compact)
                except Exception as e:  # noqa: BLE001
                    compact_error = repr(e)
                    with self._lock:
                        self._compaction_quarantined = True
                        self.quarantined_segments += 1
            out = {
                "appended": int(seg.n_tokens), "merges": int(merges),
                "segments": len(index.segments),
                "total_tokens": int(index.total_tokens),
                "compaction_quarantined": self._compaction_quarantined,
            }
            if compact_error:
                out["compaction_error"] = compact_error
            with self._lock:
                self.appends += 1
                self.compactions += merges
            fut.set_result(out)

    def _drain_inline(self) -> None:
        while True:
            with self._cond:
                ctrl = list(self._control)
                self._control.clear()
                batch = list(self._pending)
                self._pending.clear()
            if not ctrl and not batch:
                return
            if ctrl:
                self._apply_controls(ctrl)
            if batch:
                try:
                    self._flush_batch(batch)
                except BaseException:  # noqa: BLE001 — resolve, not hang
                    for e in batch:
                        try:
                            if not e[_FUT].done():
                                e[_FUT].set_result(Shutdown(e[2]))
                        except InvalidStateError:
                            pass

    def _flush_batch(self, batch: list) -> None:
        # the injected worker-crash site: OUTSIDE every recovery guard, so
        # the exception kills the worker thread and exercises the watchdog
        fault_point("worker.flush")
        # claim every future before dispatch: a client cancel() between
        # admission and flush drops the request here; once claimed,
        # set_result can no longer race a cancel and kill the worker
        batch = [e for e in batch if e[_FUT].set_running_or_notify_cancel()]
        # expire deadlines at dispatch time: the caller stops waiting NOW
        # instead of paying for a flush it no longer wants
        now = time.perf_counter()
        expired = [e for e in batch
                   if e[_DEADLINE] is not None and now > e[_DEADLINE]]
        if expired:
            batch = [e for e in batch if e[_DEADLINE] is None
                     or now <= e[_DEADLINE]]
            with self._lock:
                self.deadline_exceeded += len(expired)
            for e in expired:
                e[_FUT].set_result(DeadlineExceeded(e[2]))
        if not batch:
            return
        try:
            # the whole dispatch is guarded: the single worker thread must
            # survive ANY failure (bad pattern, a foreign flush of the
            # shared server stealing tickets, ...) — an admitted future
            # must resolve, if only to an exception
            tickets = [
                self.server.submit(pat, kind, k=k)
                for (_, pat, kind, k, _, _) in batch
            ]
            results = self.server.flush()
            outs = [results[t] for t in tickets]
        except Exception as e:  # noqa: BLE001 — the worker must survive
            for e_ in batch:
                if not e_[_FUT].done():
                    e_[_FUT].set_exception(e)
            return
        t_done = time.perf_counter()
        with self._lock:
            self.flushes += 1
            self.completed += len(batch)
            for (t0, pat, kind, _, _, _) in batch:
                self._bucket(kind, len(pat)).record((t_done - t0) * 1e3)
        for out, e in zip(outs, batch):
            e[_FUT].set_result(out)

    def _bucket(self, kind: str, m: int) -> _BucketStats:
        key = f"{kind}/{self.server._bucket_len(m)}"
        if key not in self._buckets:
            self._buckets[key] = _BucketStats(
                self.slo_p99_ms.get(kind), self.window
            )
        return self._buckets[key]

    # -- observability -------------------------------------------------------

    def metrics(self) -> dict:
        """Serving metrics snapshot (JSON-able).

        ``buckets`` maps "kind/padded-length" (one per jit program the
        server compiled) to {completed, p50_ms, p99_ms, slo_p99_ms, slo_ok,
        violations} over the last ``window`` completions; top level carries
        admitted/rejected/completed counters, the shed fraction, sustained
        qps since start, the live queue depth, and the fault counters
        (worker_restarts, retries, quarantined_segments, deadline_exceeded,
        degraded — the latter true when the served index came up with
        quarantined segments or compaction is poison-quarantined)."""
        with self._lock:
            offered = self.admitted + self.rejected
            elapsed = time.perf_counter() - self._t_start
            degraded = bool(getattr(self.server.index, "degraded", False)
                            or self._compaction_quarantined)
            return {
                "admitted": self.admitted,
                "rejected": self.rejected,
                "completed": self.completed,
                "flushes": self.flushes,
                "appends": self.appends,
                "compactions": self.compactions,
                "shed_frac": self.rejected / offered if offered else 0.0,
                "qps": self.completed / elapsed if elapsed > 0 else 0.0,
                "queue_depth": len(self._pending),
                "max_queue": self.max_queue,
                "worker_restarts": self.worker_restarts,
                "retries": self.retries,
                "quarantined_segments": self.quarantined_segments,
                "deadline_exceeded": self.deadline_exceeded,
                "degraded": degraded,
                # compaction planner telemetry (SegmentedIndex; zero/empty
                # for monolithic indexes): merge-strategy runs that fell
                # back to the O(n log n) rebuild, why the last one did,
                # and how often each strategy actually ran
                "compact_fallbacks": int(getattr(
                    self.server.index, "compact_fallbacks", 0)),
                "compact_last_fallback_reason": getattr(
                    self.server.index, "compact_last_fallback_reason", None),
                "compact_strategy_counts": dict(getattr(
                    self.server.index, "compact_strategy_counts", {}) or {}),
                "buckets": {
                    key: b.summary()
                    for key, b in sorted(self._buckets.items())
                },
            }
