"""Serving engine: batched LM generation over the cached decode step, and
the FM-index query server — the two production serve paths.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import transformer as tf
from ..sharding import MeshContext


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray       # (B, prompt+gen)
    tokens_per_s: float


def generate(
    params,
    cfg: ArchConfig,
    ctx: MeshContext,
    prompts: np.ndarray,     # (B, prompt_len) int32
    max_new_tokens: int,
    *,
    dtype=jnp.float32,
    cache_dtype=None,
    sample: Callable | None = None,   # logits (B, V) -> token (B,)
) -> GenerateResult:
    """Greedy (or custom-sampled) batched generation with a donated cache."""
    B, prompt_len = prompts.shape
    total = prompt_len + max_new_tokens
    step = jax.jit(
        lambda p, c, t, pos: tf.decode_step(p, c, t, pos, cfg, ctx),
        donate_argnums=(1,),
    )
    cache = tf.init_cache(cfg, B, total, cache_dtype or dtype)
    out = np.zeros((B, total), np.int32)
    out[:, :prompt_len] = prompts
    tok = jnp.asarray(prompts[:, :1])
    t0 = time.perf_counter()
    for pos in range(total - 1):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        if pos + 1 < prompt_len:
            tok = jnp.asarray(prompts[:, pos + 1 : pos + 2])
        else:
            nxt = (
                jnp.argmax(logits, axis=-1) if sample is None else sample(logits)
            )
            tok = nxt[:, None].astype(jnp.int32)
            out[:, pos + 1] = np.asarray(tok)[:, 0]
    dt = time.perf_counter() - t0
    return GenerateResult(out, B * (total - 1) / dt)


class FMQueryServer:
    """Thin serving wrapper over a built SequenceIndex: PAD-pads raw
    variable-length queries and returns exact-match counts."""

    def __init__(self, index):
        self.index = index

    def count(self, queries: list[np.ndarray]) -> np.ndarray:
        from ..core.fm_index import PAD

        L = max(len(q) for q in queries)
        pats = np.full((len(queries), L), PAD, np.int32)
        for i, q in enumerate(queries):
            pats[i, : len(q)] = q
        return np.asarray(self.index.count(pats))
