"""Serving engine: batched LM generation over the cached decode step, and
the FM-index query server — the two production serve paths.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import transformer as tf
from ..sharding import MeshContext


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray       # (B, prompt+gen)
    tokens_per_s: float


def generate(
    params,
    cfg: ArchConfig,
    ctx: MeshContext,
    prompts: np.ndarray,     # (B, prompt_len) int32
    max_new_tokens: int,
    *,
    dtype=jnp.float32,
    cache_dtype=None,
    sample: Callable | None = None,   # logits (B, V) -> token (B,)
) -> GenerateResult:
    """Greedy (or custom-sampled) batched generation with a donated cache.

    ``prompts`` int32[B, prompt_len]; returns all B sequences extended to
    ``prompt_len + max_new_tokens`` (int32) plus tokens/s.  The decode step
    is one jit'd program reused every position; ``sample`` maps logits
    float[B, V] -> token int[B] (None = argmax)."""
    B, prompt_len = prompts.shape
    total = prompt_len + max_new_tokens
    step = jax.jit(
        lambda p, c, t, pos: tf.decode_step(p, c, t, pos, cfg, ctx),
        donate_argnums=(1,),
    )
    cache = tf.init_cache(cfg, B, total, cache_dtype or dtype)
    out = np.zeros((B, total), np.int32)
    out[:, :prompt_len] = prompts
    tok = jnp.asarray(prompts[:, :1])
    t0 = time.perf_counter()
    for pos in range(total - 1):
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        if pos + 1 < prompt_len:
            tok = jnp.asarray(prompts[:, pos + 1 : pos + 2])
        else:
            nxt = (
                jnp.argmax(logits, axis=-1) if sample is None else sample(logits)
            )
            tok = nxt[:, None].astype(jnp.int32)
            out[:, pos + 1] = np.asarray(tok)[:, 0]
    dt = time.perf_counter() - t0
    return GenerateResult(out, B * (total - 1) / dt)


@dataclasses.dataclass
class FMQueryResult:
    """One answered request.  ``positions`` is None for count requests."""

    kind: str                       # "count" | "locate"
    count: int
    positions: np.ndarray | None = None


@dataclasses.dataclass
class FMServerStats:
    queries: int = 0
    batches: int = 0
    seconds: float = 0.0

    @property
    def qps(self) -> float:
        return self.queries / self.seconds if self.seconds else 0.0


class FMQueryServer:
    """Micro-batching FM-index query server over a built SequenceIndex.

    Mixed count/locate requests accumulate via ``submit`` and are answered
    by ``flush``: requests are grouped by (kind, length bucket), each group
    is PAD-padded to a fixed (batch, length) shape, and one jit'd index call
    dispatches per bucket — steady-state serving therefore reuses a small
    set of compiled programs no matter what request shapes arrive (the same
    playbook as fixed-shape LM decode buckets).  ``stats`` accumulates a
    tokens/s-style throughput report across flushes.
    """

    def __init__(self, index, *, length_buckets=(8, 16, 32, 64),
                 max_batch: int = 256, locate_k: int = 16,
                 completed_cap: int = 1 << 16):
        self.index = index
        self.length_buckets = tuple(sorted(length_buckets))
        self.max_batch = max_batch
        self.locate_k = locate_k
        self._queue: list[tuple[int, str, np.ndarray, int]] = []
        self._next_ticket = 0
        # answered requests retained across flushes — so a convenience
        # wrapper flushing the queue never strands an earlier submit()'s
        # result.  Bounded: beyond ``completed_cap`` the oldest tickets
        # evict (dict preserves insertion = ticket order), so a long-running
        # server (e.g. behind the async frontend, which consumes results
        # from flush()'s return value) holds O(cap) results, not O(lifetime)
        self.completed: dict[int, FMQueryResult] = {}
        self.completed_cap = completed_cap
        self.stats = FMServerStats()

    @classmethod
    def from_config(cls, index, cfg) -> "FMQueryServer":
        """Build from a BWTIndexConfig's serving knobs."""
        return cls(index, length_buckets=cfg.serve_length_buckets,
                   max_batch=cfg.serve_max_batch, locate_k=cfg.locate_k)

    def _bucket_len(self, m: int) -> int:
        for b in self.length_buckets:
            if m <= b:
                return b
        b = self.length_buckets[-1]
        while b < m:  # oversize queries: next power-of-two bucket
            b *= 2
        return b

    def _bucket_batch(self, b: int) -> int:
        out = 1
        while out < b:
            out *= 2
        return min(out, self.max_batch)  # the configured cap wins over pow2

    def submit(self, pattern: np.ndarray, kind: str = "count",
               k: int | None = None) -> int:
        """Enqueue one query; returns its ticket (int, dense, per-server).

        ``pattern`` is a 1-D int sequence over the index alphabet (values
        in [1, sigma); no PAD — padding happens at flush when the bucket
        shape is known).  ``k`` overrides the server's locate_k for this
        request only."""
        if kind not in ("count", "locate"):
            raise ValueError(f"unknown query kind {kind!r}")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append(
            (t, kind, np.asarray(pattern, np.int32),
             self.locate_k if k is None else k)
        )
        return t

    def flush(self) -> dict[int, FMQueryResult]:
        """Answer every queued request; returns {ticket: result} for this
        flush (and records them in ``self.completed``).

        Requests group into fixed (kind, pow2-batch, length-bucket) shapes,
        PAD-padded, one ``index.count``/``index.locate`` dispatch per group
        — so steady state reuses a small set of jit programs.  Works over
        any index exposing that interface (``SequenceIndex``, a restored
        checkpoint, or a ``SegmentedIndex``)."""
        from ..core.fm_index import PAD

        queue, self._queue = self._queue, []
        results: dict[int, FMQueryResult] = {}
        groups: dict[tuple[str, int, int], list[tuple[int, np.ndarray]]] = {}
        for t, kind, pat, k in queue:
            key = (kind, self._bucket_len(len(pat)), k if kind == "locate" else 0)
            groups.setdefault(key, []).append((t, pat))
        t0 = time.perf_counter()
        for (kind, L, k), items in sorted(groups.items()):
            for lo in range(0, len(items), self.max_batch):
                chunk = items[lo : lo + self.max_batch]
                B = self._bucket_batch(len(chunk))
                pats = np.full((B, L), PAD, np.int32)
                for i, (_, pat) in enumerate(chunk):
                    pats[i, : len(pat)] = pat
                if kind == "count":
                    counts = np.asarray(self.index.count(pats))
                    for i, (t, _) in enumerate(chunk):
                        results[t] = FMQueryResult("count", int(counts[i]))
                else:
                    pos, counts = self.index.locate(pats, k)
                    pos, counts = np.asarray(pos), np.asarray(counts)
                    for i, (t, _) in enumerate(chunk):
                        c = int(counts[i])
                        results[t] = FMQueryResult(
                            "locate", c, pos[i, :c].copy()
                        )
                self.stats.batches += 1
        self.stats.seconds += time.perf_counter() - t0
        self.stats.queries += len(queue)
        self.completed.update(results)
        while len(self.completed) > self.completed_cap:
            self.completed.pop(next(iter(self.completed)))
        return results

    def count(self, queries: list[np.ndarray]) -> np.ndarray:
        """Batched exact-match counts for raw variable-length queries
        (list of 1-D int sequences) -> int64[len(queries)].

        Flushes the whole queue; results for previously submit()ed tickets
        stay retrievable via ``self.completed``."""
        tickets = [self.submit(q, "count") for q in queries]
        res = self.flush()
        return np.array([res[t].count for t in tickets], np.int64)

    def locate(self, queries: list[np.ndarray], k: int | None = None):
        """First-k occurrence positions per query: list of 1-D int
        sequences -> list of int arrays (ascending positions, length =
        min(#occurrences, k)).  ``k`` applies to these queries only
        (default: the server's locate_k)."""
        tickets = [self.submit(q, "locate", k=k) for q in queries]
        res = self.flush()
        return [res[t].positions for t in tickets]

    def throughput_report(self) -> str:
        s = self.stats
        return (
            f"fm-server: {s.queries} queries in {s.batches} batches, "
            f"{s.seconds * 1e3:.1f}ms -> {s.qps:.0f} queries/s"
        )
