"""Serving layer: batched LM generation (cached decode, optional fp8 KV)
and FM-index query serving (sync micro-batching server + async
admission-controlled frontend)."""

from .engine import FMQueryServer, GenerateResult, generate  # noqa: F401
from .frontend import AsyncQueryFrontend, Rejected  # noqa: F401
