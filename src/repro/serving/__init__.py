"""Serving layer: batched LM generation (cached decode, optional fp8 KV)
and FM-index query serving."""

from .engine import FMQueryServer, GenerateResult, generate  # noqa: F401
