"""Checkpointing: atomic, async, keep-k, elastic-restore.

Layout (one directory per step, atomically renamed into place):

    ckpt_dir/
      step_000123/
        arrays.npz          flattened pytree leaves by joined key path
        meta.json           step, loader cursor, PRNG key, tree structure

Design (DESIGN.md §7):
  * atomic   — write to ``step_X.tmp`` then ``os.rename`` (POSIX atomic);
               a crash mid-save never corrupts the latest checkpoint.
  * async    — ``save_async`` snapshots to host (device_get) on the caller
               thread (cheap, overlapped with the next step's compute on
               real hardware) and does file IO on a background thread.
  * keep-k   — old steps garbage-collected after a successful save.
  * elastic  — arrays are saved UNSHARDED (host-gathered); ``restore``
               device_puts onto whatever shardings the new mesh prescribes,
               so a 512-chip checkpoint restores onto 256 chips unchanged.
  * index build — the prefix-doubling loop state (ISA, h) checkpoints the
               same way, making the paper's workload preemption-safe.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

from ..testing.faultinject import fault_point

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)  # npz-safe; restore recasts
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten(tree_like, flat: dict[str, np.ndarray]):
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(tree_like)
    treedef = paths_and_leaves[1]
    leaves = []
    for path, _ in paths_and_leaves[0]:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        # created lazily on first save: constructing a Checkpointer to
        # *read* (restore / latest_step) must not touch the filesystem
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: dict[str, Any] | None = None):
        """Synchronous atomic save."""
        flat = _flatten(tree)
        self._write(step, flat, extra or {})

    def save_async(self, step: int, tree, extra: dict[str, Any] | None = None):
        """Snapshot now (host copy), write in the background."""
        self.wait()
        flat = _flatten(tree)  # device_get = the snapshot barrier
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat, extra):
        os.makedirs(self.dir, exist_ok=True)
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        fault_point("io.write")
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        fault_point("io.write")
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        fault_point("io.rename")
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"))

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        if not os.path.isdir(self.dir):
            return []
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_raw(self, step: int | None = None):
        """(flat {keypath: np.ndarray}, meta) without a structure template.

        For callers that reconstruct typed objects from a saved manifest
        (``core/index_io`` rebuilds FM indexes whose array set and shapes
        are only known from the checkpoint itself)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return flat, meta

    def restore(self, tree_like, step: int | None = None, shardings=None):
        """Restore into the structure of ``tree_like``.  With ``shardings``
        (a matching pytree of NamedSharding), arrays are placed directly
        onto the new mesh — elastic re-mesh is free because the on-disk
        format is unsharded."""
        flat, meta = self.restore_raw(step)
        tree = _unflatten(tree_like, flat)
        # recast to the reference dtypes (bf16 round-trips via f32 on disk)
        tree = jax.tree_util.tree_map(
            lambda x, ref: np.asarray(x).astype(ref.dtype), tree, tree_like
        )
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        else:
            tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
        return tree, meta
