"""AdamW in pure JAX with sharded state and warmup-cosine schedule.

State lives in float32 regardless of param dtype (bf16-safe), sharded like
the parameters (the spec system's shardings apply leaf-for-leaf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def init_opt_state(params) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree_util.tree_map(zeros32, params),
        "v": jax.tree_util.tree_map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # no weight decay on norms/biases/scalars
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return newp, m, v

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    new_m = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    new_v = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
