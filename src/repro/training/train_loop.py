"""jit'd train/eval steps with donation, optional gradient compression, and
the restartable training driver.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import transformer as tf
from ..sharding import MeshContext
from . import compression
from .checkpoint import Checkpointer
from .optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    remat_policy: str = "full"            # full | dots | none
    compress_grads: bool = False          # int8 + error feedback
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    log_every: int = 10


def make_train_step(cfg: ArchConfig, ctx: MeshContext, tcfg: TrainConfig):
    """Returns jit'd (state, batch) -> (state, metrics).

    state = {params, opt, err?}; donated for in-place updates.
    """

    def step(state, batch):
        params = state["params"]

        def loss(p):
            return tf.loss_fn(p, batch, cfg, ctx,
                              remat_policy=tcfg.remat_policy)

        loss_val, grads = jax.value_and_grad(loss)(params)
        if tcfg.compress_grads:
            grads, new_err = compression.compressed_grads(grads, state["err"])
        params, opt, metrics = adamw_update(grads, state["opt"], params, tcfg.opt)
        new_state = {"params": params, "opt": opt}
        if tcfg.compress_grads:
            new_state["err"] = new_err
        metrics = dict(metrics, loss=loss_val)
        return new_state, metrics

    return jax.jit(step, donate_argnums=(0,))


def init_train_state(cfg: ArchConfig, key, tcfg: TrainConfig,
                     dtype=jnp.float32):
    params = tf.init_model(cfg, key, dtype)
    state = {"params": params, "opt": init_opt_state(params)}
    if tcfg.compress_grads:
        state["err"] = compression.init_error_state(params)
    return state


def train(
    cfg: ArchConfig,
    ctx: MeshContext,
    tcfg: TrainConfig,
    loader,
    num_steps: int,
    *,
    ckpt_dir: str | None = None,
    resume: bool = False,
    seed: int = 0,
    dtype=jnp.float32,
    log: Callable[[str], None] = print,
) -> dict[str, Any]:
    """Restartable training driver (examples + integration tests).

    Checkpoints carry the loader cursor; ``resume=True`` continues the exact
    trajectory (bitwise — verified by tests/test_checkpoint.py).
    """
    step_fn = make_train_step(cfg, ctx, tcfg)
    state = init_train_state(cfg, jax.random.key(seed), tcfg, dtype)
    start = 0
    ckpt = Checkpointer(ckpt_dir, keep=tcfg.keep_checkpoints) if ckpt_dir else None
    if resume and ckpt and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(state)
        start = meta["step"]
        log(f"resumed at step {start}")

    losses = []
    t0 = time.time()
    for i in range(start, num_steps):
        batch = {k: jnp.asarray(v) for k, v in loader.batch(i).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if tcfg.log_every and (i + 1) % tcfg.log_every == 0:
            log(
                f"step {i + 1}/{num_steps} loss={losses[-1]:.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"({(time.time() - t0) / max(1, i + 1 - start):.2f}s/step)"
            )
        if ckpt and tcfg.checkpoint_every and (i + 1) % tcfg.checkpoint_every == 0:
            ckpt.save_async(i + 1, state)
    if ckpt:
        ckpt.wait()
        ckpt.save(num_steps, state)
    return {"state": state, "losses": losses}
