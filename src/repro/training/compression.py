"""Int8 gradient compression with error feedback.

For bandwidth-constrained inter-pod links (DESIGN.md §7): gradients are
quantised to int8 with a per-tensor scale before the (simulated) cross-pod
reduce; the quantisation residual is carried in an error-feedback buffer so
the scheme stays unbiased over time (Seide et al. / 1-bit-Adam lineage).

``compressed_grads`` plugs between ``jax.grad`` and the optimizer; tests
verify a toy regression still converges with compression on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def _quantize(x):
    """per-tensor symmetric int8; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_leaf(g, err):
    """one leaf: returns (g_hat, new_err).  g_hat is what the wire carries
    (dequantised int8); err accumulates the residual."""
    g32 = g.astype(jnp.float32) + err
    q, scale = _quantize(g32)
    g_hat = _dequantize(q, scale)
    return g_hat.astype(g.dtype), g32 - g_hat


def compressed_grads(grads, err_state):
    """Apply int8 + error feedback across a grad tree."""
    out = jax.tree_util.tree_map(compress_leaf, grads, err_state)
    g_hat = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    new_err = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple)
    )
    return g_hat, new_err
