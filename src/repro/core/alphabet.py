"""Alphabet handling for sequence indexing.

Conventions used across the library:

* Sequences are dense ``int32`` token arrays.
* Token id ``0`` is reserved for the sentinel ``$`` (lexicographically
  smallest, unique, and terminal).  Real symbols are ``>= 1``.
* ``encode_bytes`` maps raw bytes to ``byte + 1`` so that arbitrary binary
  text (Pizza&Chili corpora, UTF-8 English, protein FASTA, ...) fits the
  convention with alphabet size 257.
"""

from __future__ import annotations

import numpy as np

SENTINEL = 0

# Canonical biological alphabets (id 0 is the sentinel everywhere).
DNA = "ACGT"
PROTEIN = "ACDEFGHIKLMNPQRSTVWY"

BYTE_SIGMA = 257  # 256 byte values shifted by one + sentinel


def encode_bytes(data: bytes) -> np.ndarray:
    """Encode raw bytes as int32 tokens in [1, 256]."""
    return np.frombuffer(data, dtype=np.uint8).astype(np.int32) + 1


def decode_bytes(tokens: np.ndarray) -> bytes:
    """Inverse of :func:`encode_bytes`; drops any sentinel tokens."""
    tokens = np.asarray(tokens)
    tokens = tokens[tokens != SENTINEL]
    return (tokens - 1).astype(np.uint8).tobytes()


def encode_str(text: str, alphabet: str | None = None) -> np.ndarray:
    """Encode a string.  With ``alphabet`` given, ids are dense in
    [1, len(alphabet)]; otherwise byte encoding is used."""
    if alphabet is None:
        return encode_bytes(text.encode("utf-8"))
    lut = {c: i + 1 for i, c in enumerate(alphabet)}
    return np.array([lut[c] for c in text], dtype=np.int32)


def decode_str(tokens: np.ndarray, alphabet: str | None = None) -> str:
    if alphabet is None:
        return decode_bytes(tokens).decode("utf-8", errors="replace")
    tokens = np.asarray(tokens)
    return "".join(alphabet[t - 1] for t in tokens if t != SENTINEL)


def append_sentinel(tokens: np.ndarray) -> np.ndarray:
    """Append the terminal sentinel.  Raises if a sentinel is already
    present anywhere (it must be unique)."""
    tokens = np.asarray(tokens, dtype=np.int32)
    if tokens.size and tokens.min() <= SENTINEL:
        raise ValueError("input tokens must be >= 1 (0 is the sentinel)")
    return np.concatenate([tokens, np.array([SENTINEL], dtype=np.int32)])


def sigma_of(tokens: np.ndarray) -> int:
    """Smallest alphabet size covering ``tokens`` (includes the sentinel)."""
    return int(np.asarray(tokens).max()) + 1
