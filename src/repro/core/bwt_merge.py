"""Rebuild-free BWT merge of two adjacent index segments (Sirén-style).

``SegmentedIndex.compact`` used to throw away per-segment BWTs and rebuild
the merged segment from raw tokens — O(total tokens) of suffix sorting per
compaction.  This module merges two built FM-indexes directly, the way
Sirén's *BWT for terabases* (arXiv:1511.00898) grows terabase BWTs: the
merged suffix order is an **interleave** of the two segments' suffix
orders, and the interleave bitvector is computed by LF-stepping the right
segment's symbols through the left segment's FM-index — one fused
``kernels/ops`` rank call per step (Pallas popcount kernel on TPU, jnp
fallback elsewhere), never touching raw tokens or running a sort.

Let ``TA``/``TB`` be the two segments' *prepared* texts (each a
concatenation of sentinel-terminated, pad-filled documents — see
``pipeline.prepare_tokens``) and ``U = TA · TB`` the merged text.  Because
every document carries its own sentinel and pad run:

* suffixes of ``U`` starting inside ``TB`` are literally the standalone
  suffixes of ``TB`` (it sits at the end), and
* suffixes starting inside ``TA`` keep their standalone relative order —
  **provided TA is a single prepared document**: comparisons between two
  TA suffixes then always resolve at TA's unique sentinel or inside its
  trailing pad run, before the continuation into ``TB`` can matter.  (A
  multi-document TA can contain one suffix that is a proper prefix of
  another — e.g. two identical documents — whose order legitimately
  depends on what follows, so a multi-document segment may only ever be
  the RIGHT operand.  ``segments.compact`` plans its fold accordingly.)

So ``SA(U)`` interleaves ``SA(TA)`` and ``SA(TB)``, and ``BWT(U)`` is the
corresponding interleave of the two BWTs with exactly two cells exchanged
(the wrap-around characters at each side's row of suffix 0).  The
interleave is produced by one backward walk over ``TB``, tracked entirely
inside the two indexes:

    I(j) = #{TA suffixes (continued into TB) < TB[j:]}
         = C_A[c] + Occ_A(c, I(j+1))
           + [c = lastA] * ([rowB < r(j+1)] - [rowA < I(j+1)])
    r(j) = C_B[c] + Occ_B(c, r(j+1)) + [c = lastB] * [r(j+1) <= rowB]

with ``c = BWT_B[r(j+1)] = TB[j]``, ``lastX = BWT_X[rowX]`` the last
character of each text and ``r(j) = ISA_B[j]``.  The first correction
accounts for TA's final suffix continuing into ``TB`` instead of ending;
the second repairs the cyclic wrap entry that ``bwt_from_sa`` stores at
``rowB`` (exact for any multi-document right operand).  The walk anchors
at ``I(nB-1) = C_A[lastB]``, ``r(nB-1) = C_B[lastB]`` — the shortest
suffix of ``TB`` sorts before every longer suffix sharing its first
character.

The merged SA sample is spliced from the per-segment samples: left rows
keep their values, right rows shift by ``len(TA)`` (requiring the stride
to divide ``len(TA)`` — checked by ``merge_eligible``), and the merged
stream re-packs at the merged bit width through the same
``fm_index.sample_arrays_from_rows`` constructor the rebuild path uses.
The result is bit-identical to rebuilding over ``U`` from raw tokens
(asserted per-trajectory by ``tests/test_lifecycle_fuzz.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels import ops
from ..testing.faultinject import fault_point
from .fm_index import (
    FMIndex,
    _next_pow2,
    build_fm_index,
    decode_sa_values,
    packed_symbol,
    sample_arrays_from_rows,
    sample_marked_rows,
)


def merge_eligible(left: FMIndex, right: FMIndex) -> str | None:
    """Why the pair cannot BWT-merge, or None when it can.

    The left operand must additionally be a *single prepared document*
    (callers know the document structure; this function checks only what
    the indexes expose).  The rebuild path remains the fallback (and the
    bit-identity oracle) for every ineligible pair.
    """
    for side, fm in (("left", left), ("right", right)):
        if not isinstance(fm, FMIndex):
            return f"{side} segment is not a single-device FMIndex"
    sig_l = (left.sigma, left.sample_rate, left.bits, left.sa_sample_rate)
    sig_r = (right.sigma, right.sample_rate, right.bits, right.sa_sample_rate)
    if sig_l != sig_r:
        return f"mixed layouts {sig_l} != {sig_r}"
    for side, fm in (("left", left), ("right", right)):
        if fm.length % fm.sample_rate:
            return f"{side} length {fm.length} not a block multiple"
    if left.sa_sample_rate:
        if left.sa_marks is None or right.sa_marks is None:
            return "missing SA sample arrays"
        if left.length % left.sa_sample_rate:
            return (
                f"SA stride {left.sa_sample_rate} does not divide left "
                f"length {left.length}"
            )
    return None


def _bucket_rows(arr, rows: int, fill):
    """Pad a row-major array to ``rows`` rows so the walk's jit program is
    reused across merges within the same power-of-two bucket."""
    if arr.shape[0] == rows:
        return arr
    pad = jnp.broadcast_to(
        fill, (rows - arr.shape[0],) + arr.shape[1:]
    ).astype(arr.dtype)
    return jnp.concatenate([arr, pad])


def _side_arrays(fm: FMIndex, nb_bucket: int):
    """(fused, blocks, occ) of one side, padded to the block bucket.  Pad
    rows are never addressed (block ids clamp to the true count)."""
    if fm.bits:
        return _bucket_rows(fm.fused, nb_bucket, 0), None, None
    r = fm.sample_rate
    blocks = _bucket_rows(fm.bwt.reshape(fm.n_blocks, r), nb_bucket, 0)
    occ = _bucket_rows(fm.occ_samples[:-1], nb_bucket, 0)
    return None, blocks, occ


def _occ_side(fused, blocks, occ, nb_real, c, p, *, r: int, bits: int,
              sigma: int):
    """Occ(c_i, p_i) on one side — the fused kernels/ops rank dispatch
    (p == nb_real * r folds into the last block, as in ``occ_batch``)."""
    blk = jnp.minimum(p // r, nb_real - 1)
    cut = p - blk * r
    if bits:
        return ops.rank_packed(fused, blk, c, cut, bits=bits, sigma=sigma)
    return occ[blk, c] + ops.rank_unpacked(blocks, blk, c, cut)


@functools.partial(jax.jit, static_argnames=("sigma", "bits", "r"))
def _merge_walk(fusedA, blocksA, occA, cA, nbA, rowA, lastA,
                fusedB, blocksB, occB, cB, nbB, rowB, lastB, nB,
                *, sigma: int, bits: int, r: int):
    """Interleave counts ``ins[row]`` = #{left suffixes < right suffix of
    that row}, for every row of the right index.

    Array shapes are bucket-padded and the true sizes (``nbA``/``nbB``
    block counts, ``nB`` text length) are traced scalars, so steady-state
    compaction re-hits one compiled program per bucket shape.  The right
    side's symbol and LF maps are precomputed in two batched dispatches;
    the walk proper then issues ONE fused rank call (on the left index)
    per step.
    """
    n_bucket = blocksB.shape[0] * r if bits == 0 else fusedB.shape[0] * r
    rows = jnp.arange(n_bucket, dtype=jnp.int32)
    # right side, batched: symbol of every row, then the (wrap-corrected)
    # LF map.  Pad rows decode garbage that the walk never visits.
    if bits:
        c_all = packed_symbol(fusedB, rows // r, rows % r,
                              sigma=sigma, bits=bits)
    else:
        c_all = blocksB[rows // r, rows % r]
    c_all = jnp.clip(c_all, 0, sigma - 1)
    lf_all = (
        cB[c_all]
        + _occ_side(fusedB, blocksB, occB, nbB, c_all, rows,
                    r=r, bits=bits, sigma=sigma)
        + ((c_all == lastB) & (rows <= rowB)).astype(jnp.int32)
    )

    ins0 = jnp.zeros(n_bucket, jnp.int32)
    # anchor: the length-1 suffix TB[nB-1:] sorts before every longer
    # suffix sharing its first character lastB
    I0, r0 = cA[lastB], cB[lastB]
    ins0 = ins0.at[r0].set(I0)

    def body(_, state):
        I, rr, ins = state
        c = c_all[rr]
        corr = jnp.where(
            c == lastA,
            (rowB < rr).astype(jnp.int32) - (rowA < I).astype(jnp.int32),
            0,
        )
        occ = _occ_side(fusedA, blocksA, occA, nbA, c[None], I[None],
                        r=r, bits=bits, sigma=sigma)[0]
        I_new = cA[c] + occ + corr
        r_new = lf_all[rr]
        return I_new, r_new, ins.at[r_new].set(I_new)

    _, _, ins = lax.fori_loop(0, nB - 1, body, (I0, r0, ins0))
    return ins


def merge_fm_indexes(
    left: FMIndex, right: FMIndex, *, compress_sa: bool | None = None,
    pack: bool | None = None,
) -> FMIndex:
    """BWT of ``T_left · T_right`` from the two built indexes — no sort.

    PRECONDITION (not checkable from the indexes alone): ``left`` indexes a
    single prepared document; ``right`` may be any document concatenation.
    ``merge_eligible`` must have returned None.  ``compress_sa``/``pack``
    as in ``build_fm_index`` — pass the same knobs the rebuild path would
    use so both construct the identical layout.
    """
    reason = merge_eligible(left, right)
    if reason:
        raise ValueError(f"cannot merge: {reason}")
    nA, nB = left.length, right.length
    r, sigma, bits = left.sample_rate, left.sigma, left.bits
    nbA_b = _next_pow2(left.n_blocks)
    nbB_b = _next_pow2(right.n_blocks)
    fA, bA, oA = _side_arrays(left, nbA_b)
    fB, bB, oB = _side_arrays(right, nbB_b)
    ins = np.asarray(_merge_walk(
        fA, bA, oA, left.c_array, jnp.asarray(left.n_blocks, jnp.int32),
        left.row, left.bwt[left.row],
        fB, bB, oB, right.c_array, jnp.asarray(right.n_blocks, jnp.int32),
        right.row, right.bwt[right.row], jnp.asarray(nB, jnp.int32),
        sigma=sigma, bits=bits, r=r,
    ))[:nB].astype(np.int64)
    # a crash here leaves the operands untouched and no merged index —
    # callers (segments.compact, the frontend's growth retry) must recover
    # by retrying or keeping the pre-merge generation serving
    fault_point("merge.mid")

    # splice: right rows land at ins[k] + k, left rows fill the gaps in
    # order; then exchange the two wrap cells (each side's row of suffix 0
    # must hold the OTHER side's last character in the merged text)
    rowA, rowB = int(left.row), int(right.row)
    bwtA = np.asarray(left.bwt)[:nA]
    bwtB = np.asarray(right.bwt)[:nB]
    pos_b = ins + np.arange(nB)
    is_b = np.zeros(nA + nB, bool)
    is_b[pos_b] = True
    pos_a = np.nonzero(~is_b)[0]
    merged = np.empty(nA + nB, np.int32)
    merged[pos_a] = bwtA
    merged[pos_b] = bwtB
    merged[pos_a[rowA]] = bwtB[rowB]
    merged[pos_b[rowB]] = bwtA[rowA]

    sa_samples = None
    srate = left.sa_sample_rate
    if srate:
        rows_m = np.concatenate([
            pos_a[sample_marked_rows(left)],
            pos_b[sample_marked_rows(right)],
        ])
        vals_m = np.concatenate([
            decode_sa_values(left),
            decode_sa_values(right) + nA,
        ]).astype(np.int32)
        order = np.argsort(rows_m, kind="stable")
        sa_samples = sample_arrays_from_rows(
            rows_m[order], vals_m[order], nA + nB, srate,
            compress=compress_sa,
        )

    return build_fm_index(
        jnp.asarray(merged), jnp.asarray(pos_a[rowA], jnp.int32), sigma, r,
        pack=bool(bits) if pack is None else pack,
        sa_samples=sa_samples, sa_sample_rate=srate,
    )
