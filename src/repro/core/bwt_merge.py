"""Rebuild-free BWT merge of two adjacent index segments (Sirén-style).

``SegmentedIndex.compact`` used to throw away per-segment BWTs and rebuild
the merged segment from raw tokens — O(total tokens) of suffix sorting per
compaction.  This module merges two built FM-indexes directly, the way
Sirén's *BWT for terabases* (arXiv:1511.00898) grows terabase BWTs: the
merged suffix order is an **interleave** of the two segments' suffix
orders, and the interleave bitvector is computed by LF-stepping the right
segment's symbols through the left segment's FM-index — one fused
``kernels/ops`` rank call per step (Pallas popcount kernel on TPU, jnp
fallback elsewhere), never touching raw tokens or running a sort.

Let ``TA``/``TB`` be the two segments' *prepared* texts (each a
concatenation of sentinel-terminated, pad-filled documents — see
``pipeline.prepare_tokens``) and ``U = TA · TB`` the merged text.  Because
every document carries its own sentinel and pad run:

* suffixes of ``U`` starting inside ``TB`` are literally the standalone
  suffixes of ``TB`` (it sits at the end), and
* suffixes starting inside ``TA`` keep their standalone relative order —
  **provided TA is context-order safe against TB**
  (``context_order_safe``).  A single prepared document always is:
  comparisons between two of its suffixes resolve at its unique sentinel
  or inside its trailing pad run, before the continuation into ``TB``
  can matter.  A multi-document TA can contain one suffix that is a
  proper prefix of another — e.g. two identical documents — whose order
  legitimately depends on what follows; the exact token-level check
  admits such a text whenever the actual continuation preserves the
  order, lifting the former "multi-document texts only on the RIGHT"
  restriction (``segments._plan_run`` checks it per operand, falling
  back to a rebuild — now counted and warned — when it fails).

So ``SA(U)`` interleaves ``SA(TA)`` and ``SA(TB)``, and ``BWT(U)`` is the
corresponding interleave of the two BWTs with exactly two cells exchanged
(the wrap-around characters at each side's row of suffix 0).  The
interleave is produced by one backward walk over ``TB``, tracked entirely
inside the two indexes:

    I(j) = #{TA suffixes (continued into TB) < TB[j:]}
         = C_A[c] + Occ_A(c, I(j+1))
           + [c = lastA] * ([rowB < r(j+1)] - [rowA < I(j+1)])
    r(j) = C_B[c] + Occ_B(c, r(j+1)) + [c = lastB] * [r(j+1) <= rowB]

with ``c = BWT_B[r(j+1)] = TB[j]``, ``lastX = BWT_X[rowX]`` the last
character of each text and ``r(j) = ISA_B[j]``.  The first correction
accounts for TA's final suffix continuing into ``TB`` instead of ending;
the second repairs the cyclic wrap entry that ``bwt_from_sa`` stores at
``rowB`` (exact for any multi-document right operand).  The walk anchors
at ``I(nB-1) = C_A[lastB]``, ``r(nB-1) = C_B[lastB]`` — the shortest
suffix of ``TB`` sorts before every longer suffix sharing its first
character.

The merged SA sample is spliced from the per-segment samples: left rows
keep their values, right rows shift by ``len(TA)`` (requiring the stride
to divide ``len(TA)`` — checked by ``merge_eligible``), and the merged
stream re-packs at the merged bit width through the same
``fm_index.sample_arrays_from_rows`` constructor the rebuild path uses.
The result is bit-identical to rebuilding over ``U`` from raw tokens
(asserted per-trajectory by ``tests/test_lifecycle_fuzz.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels import ops
from ..testing.faultinject import fault_point
from .fm_index import (
    FMIndex,
    _next_pow2,
    build_fm_index,
    decode_sa_values,
    packed_symbol,
    sample_arrays_from_rows,
    sample_marked_rows,
    stack_rank_arrays,
)


def merge_eligible(left: FMIndex, right: FMIndex) -> str | None:
    """Why the pair cannot BWT-merge, or None when it can.

    The left operand's text must additionally be *context-order safe*
    against the right's (``context_order_safe``; single prepared
    documents always are — callers know the document structure and
    tokens, this function checks only what the indexes expose).  The
    rebuild path remains the fallback (and the bit-identity oracle) for
    every ineligible pair.
    """
    for side, fm in (("left", left), ("right", right)):
        if not isinstance(fm, FMIndex):
            return f"{side} segment is not a single-device FMIndex"
    sig_l = (left.sigma, left.sample_rate, left.bits, left.sa_sample_rate)
    sig_r = (right.sigma, right.sample_rate, right.bits, right.sa_sample_rate)
    if sig_l != sig_r:
        return f"mixed layouts {sig_l} != {sig_r}"
    for side, fm in (("left", left), ("right", right)):
        if fm.length % fm.sample_rate:
            return f"{side} length {fm.length} not a block multiple"
    if left.sa_sample_rate:
        if left.sa_marks is None or right.sa_marks is None:
            return "missing SA sample arrays"
        if left.length % left.sa_sample_rate:
            return (
                f"SA stride {left.sa_sample_rate} does not divide left "
                f"length {left.length}"
            )
    return None


def _bucket_rows(arr, rows: int, fill):
    """Pad a row-major array to ``rows`` rows so the walk's jit program is
    reused across merges within the same power-of-two bucket."""
    if arr.shape[0] == rows:
        return arr
    pad = jnp.broadcast_to(
        fill, (rows - arr.shape[0],) + arr.shape[1:]
    ).astype(arr.dtype)
    return jnp.concatenate([arr, pad])


def _side_arrays(fm: FMIndex, nb_bucket: int):
    """(fused, blocks, occ) of one side, padded to the block bucket.  Pad
    rows are never addressed (block ids clamp to the true count)."""
    if fm.bits:
        return _bucket_rows(fm.fused, nb_bucket, 0), None, None
    r = fm.sample_rate
    blocks = _bucket_rows(fm.bwt.reshape(fm.n_blocks, r), nb_bucket, 0)
    occ = _bucket_rows(fm.occ_samples[:-1], nb_bucket, 0)
    return None, blocks, occ


def _occ_side(fused, blocks, occ, nb_real, c, p, *, r: int, bits: int,
              sigma: int):
    """Occ(c_i, p_i) on one side — the fused kernels/ops rank dispatch
    (p == nb_real * r folds into the last block, as in ``occ_batch``)."""
    blk = jnp.minimum(p // r, nb_real - 1)
    cut = p - blk * r
    return ops.rank_walkers(fused, blocks, occ, blk, c, cut,
                            bits=bits, sigma=sigma)


@functools.partial(jax.jit, static_argnames=("sigma", "bits", "r"))
def _merge_walk(fusedA, blocksA, occA, cA, nbA, rowA, lastA,
                fusedB, blocksB, occB, cB, nbB, rowB, lastB, nB,
                *, sigma: int, bits: int, r: int):
    """Interleave counts ``ins[row]`` = #{left suffixes < right suffix of
    that row}, for every row of the right index.

    Array shapes are bucket-padded and the true sizes (``nbA``/``nbB``
    block counts, ``nB`` text length) are traced scalars, so steady-state
    compaction re-hits one compiled program per bucket shape.  The right
    side's symbol and LF maps are precomputed in two batched dispatches;
    the walk proper then issues ONE fused rank call (on the left index)
    per step.
    """
    n_bucket = blocksB.shape[0] * r if bits == 0 else fusedB.shape[0] * r
    rows = jnp.arange(n_bucket, dtype=jnp.int32)
    # right side, batched: symbol of every row, then the (wrap-corrected)
    # LF map.  Pad rows decode garbage that the walk never visits.
    if bits:
        c_all = packed_symbol(fusedB, rows // r, rows % r,
                              sigma=sigma, bits=bits)
    else:
        c_all = blocksB[rows // r, rows % r]
    c_all = jnp.clip(c_all, 0, sigma - 1)
    lf_all = (
        cB[c_all]
        + _occ_side(fusedB, blocksB, occB, nbB, c_all, rows,
                    r=r, bits=bits, sigma=sigma)
        + ((c_all == lastB) & (rows <= rowB)).astype(jnp.int32)
    )

    ins0 = jnp.zeros(n_bucket, jnp.int32)
    # anchor: the length-1 suffix TB[nB-1:] sorts before every longer
    # suffix sharing its first character lastB
    I0, r0 = cA[lastB], cB[lastB]
    ins0 = ins0.at[r0].set(I0)

    def body(_, state):
        I, rr, ins = state
        c = c_all[rr]
        corr = jnp.where(
            c == lastA,
            (rowB < rr).astype(jnp.int32) - (rowA < I).astype(jnp.int32),
            0,
        )
        occ = _occ_side(fusedA, blocksA, occA, nbA, c[None], I[None],
                        r=r, bits=bits, sigma=sigma)[0]
        I_new = cA[c] + occ + corr
        r_new = lf_all[rr]
        return I_new, r_new, ins.at[r_new].set(I_new)

    _, _, ins = lax.fori_loop(0, nB - 1, body, (I0, r0, ins0))
    return ins


def merge_fm_indexes(
    left: FMIndex, right: FMIndex, *, compress_sa: bool | None = None,
    pack: bool | None = None,
) -> FMIndex:
    """BWT of ``T_left · T_right`` from the two built indexes — no sort.

    PRECONDITION (not checkable from the indexes alone): ``left``'s text
    is *context-order safe* against ``right``'s
    (``context_order_safe`` — a single prepared document always is);
    ``right`` may be any document concatenation.  ``merge_eligible`` must
    have returned None.  ``compress_sa``/``pack`` as in
    ``build_fm_index`` — pass the same knobs the rebuild path would use
    so both construct the identical layout.
    """
    reason = merge_eligible(left, right)
    if reason:
        raise ValueError(f"cannot merge: {reason}")
    nA, nB = left.length, right.length
    r, sigma, bits = left.sample_rate, left.sigma, left.bits
    nbA_b = _next_pow2(left.n_blocks)
    nbB_b = _next_pow2(right.n_blocks)
    fA, bA, oA = _side_arrays(left, nbA_b)
    fB, bB, oB = _side_arrays(right, nbB_b)
    ins = np.asarray(_merge_walk(
        fA, bA, oA, left.c_array, jnp.asarray(left.n_blocks, jnp.int32),
        left.row, left.bwt[left.row],
        fB, bB, oB, right.c_array, jnp.asarray(right.n_blocks, jnp.int32),
        right.row, right.bwt[right.row], jnp.asarray(nB, jnp.int32),
        sigma=sigma, bits=bits, r=r,
    ))[:nB].astype(np.int64)
    # a crash here leaves the operands untouched and no merged index —
    # callers (segments.compact, the frontend's growth retry) must recover
    # by retrying or keeping the pre-merge generation serving
    fault_point("merge.mid")

    # splice: right rows land at ins[k] + k, left rows fill the gaps in
    # order; then exchange the two wrap cells (each side's row of suffix 0
    # must hold the OTHER side's last character in the merged text)
    rowA, rowB = int(left.row), int(right.row)
    bwtA = np.asarray(left.bwt)[:nA]
    bwtB = np.asarray(right.bwt)[:nB]
    pos_b = ins + np.arange(nB)
    is_b = np.zeros(nA + nB, bool)
    is_b[pos_b] = True
    pos_a = np.nonzero(~is_b)[0]
    merged = np.empty(nA + nB, np.int32)
    merged[pos_a] = bwtA
    merged[pos_b] = bwtB
    merged[pos_a[rowA]] = bwtB[rowB]
    merged[pos_b[rowB]] = bwtA[rowA]

    sa_samples = None
    srate = left.sa_sample_rate
    if srate:
        rows_m = np.concatenate([
            pos_a[sample_marked_rows(left)],
            pos_b[sample_marked_rows(right)],
        ])
        vals_m = np.concatenate([
            decode_sa_values(left),
            decode_sa_values(right) + nA,
        ]).astype(np.int32)
        order = np.argsort(rows_m, kind="stable")
        sa_samples = sample_arrays_from_rows(
            rows_m[order], vals_m[order], nA + nB, srate,
            compress=compress_sa,
        )

    return build_fm_index(
        jnp.asarray(merged), jnp.asarray(pos_a[rowA], jnp.int32), sigma, r,
        pack=bool(bits) if pack is None else pack,
        sa_samples=sa_samples, sa_sample_rate=srate,
    )


# -- k-way merge --------------------------------------------------------------
#
# ``merge_kway`` generalizes the pairwise walk to a whole compaction run:
# ONE right-to-left walk over U = T_1 ··· T_k maintains k interleave
# states I_j — #{T_j suffixes (continued into the rest of U) < the current
# U-suffix} — updated per step as
#
#     I_j <- C_j[c] + Occ_j(c, I_j) + [c = last_j] * (NEXT_j - [row_j < I_j])
#
# with NEXT_j = [row_{j+1} < I_{j+1}] for j < k and NEXT_k = 1: segment
# j's final suffix continues into segment j+1's first suffix (the last
# segment's continues into nothing, which sorts before everything — the
# pairwise anchor).  At k = 2 this is exactly the pairwise recurrence
# pair.  The current suffix's merged position is simply sum_j I_j, and the
# walk's state at a segment boundary IS the next segment's entry state, so
# the k-1 walked texts chain through one loop: n - n_1 sequential steps
# total (the first text is never walked), each issuing ONE batched rank
# dispatch over a pow2-bucket-stacked array covering every walker.  The
# pairwise fold pays the same walk steps but rebuilds and re-splices every
# intermediate accumulator — Theta(n * k / 2) splice + occ-sample work vs
# the k-way walk's single Theta(n) splice.


def context_order_safe(text, continuation, *, budget: int = 1 << 24) -> bool:
    """True when ``text``'s standalone suffix order survives having
    ``continuation`` appended after it (exact, token-level).

    Standalone, a suffix that is a proper prefix of another sorts FIRST
    (shorter-first: ``suffix_array.OVERFLOW_RANK``).  In context the
    shorter suffix continues into the following text ``G`` while the
    longer continues inside ``text`` — the pair flips iff ``G`` compares
    greater.  Every tied pair shares its comparison outcome with the
    length-1 tie at the same internal position, so safety reduces to: for
    every p < n-1 with ``text[p] == text[-1]``, require
    ``G <= text[p+1:] + G``.  A single prepared document is always safe
    (its sentinel is unique and its pads sort above every real token,
    including the continuation's first); a multi-document text is unsafe
    only when a document tail recurs with an adverse continuation.
    Returns False, conservatively, when the scan exceeds ``budget``
    token comparisons — callers fall back to the rebuild path.
    """
    T = np.asarray(text, np.int64)
    G = np.asarray(continuation, np.int64)
    n, g = len(T), len(G)
    if n == 0 or g == 0:
        return True
    S = np.concatenate([T[1:], G])  # S[p:] = text[p+1:] + G
    cand = np.nonzero(T[:-1] == T[-1])[0]
    work, i = cand.size, 0
    while cand.size and i < g:
        if work > budget:
            return False
        s = S[cand + i]
        if np.any(s < G[i]):
            return False        # the longer suffix's side is smaller: flip
        cand = cand[s == G[i]]  # still tied: compare one token deeper
        work += cand.size
        i += 1
    # survivors tie through all of G: the shorter suffix ends first and
    # sorts first, matching the standalone order
    return True


def kway_eligible(fms: list[FMIndex]) -> str | None:
    """Why this ordered run of indexes cannot k-way merge, or None.

    Layout conditions only: context-order safety of every operand but the
    last (``context_order_safe`` — callers know the document structure
    and token content) is the caller's responsibility, exactly as the
    pairwise left-operand precondition is for ``merge_fm_indexes``.
    """
    if len(fms) < 2:
        return "k-way merge needs at least 2 segments"
    for i, fm in enumerate(fms):
        if not isinstance(fm, FMIndex):
            return f"segment {i} is not a single-device FMIndex"
    f0 = fms[0]
    sig0 = (f0.sigma, f0.sample_rate, f0.bits, f0.sa_sample_rate)
    for i, fm in enumerate(fms):
        sig = (fm.sigma, fm.sample_rate, fm.bits, fm.sa_sample_rate)
        if sig != sig0:
            return f"mixed layouts {sig} != {sig0}"
        if fm.length % fm.sample_rate:
            return f"segment {i} length {fm.length} not a block multiple"
        if f0.sa_sample_rate:
            if fm.sa_marks is None:
                return "missing SA sample arrays"
            if i < len(fms) - 1 and fm.length % f0.sa_sample_rate:
                return (
                    f"SA stride {f0.sa_sample_rate} does not divide "
                    f"segment {i} length {fm.length}"
                )
    return None


def kway_walk_steps(lengths) -> int:
    """Sequential rank steps of a k-way merge over prepared ``lengths``:
    everything but the first text is walked, minus the anchor state.  The
    pairwise fold (largest text leftmost) pays the same count — its extra
    cost is the per-fold intermediate splice/rebuild, not the walk."""
    lengths = list(lengths)
    return max(0, sum(lengths[1:]) - 1)


@functools.partial(jax.jit, static_argnames=("sigma", "bits", "r", "k_pad"))
def _kway_walk(fusedS, blocksS, occS, c_mat, nb_vec, row_vec, last_vec,
               n_vec, k_actual, *, sigma: int, bits: int, r: int,
               k_pad: int):
    """Interleave counts for every walked row of every walked segment:
    ``ins[s, row]`` = #{suffixes of OTHER segments < segment s's suffix of
    that row}, for s in [1, k).  Merged position = ins[s, row] + row.

    One fused ``ops.rank_walkers`` dispatch per step ranks ALL walkers
    against their segments through the ``stack_rank_arrays`` bucket;
    shapes are pow2-bucketed (``k_pad`` lanes x padded blocks) and true
    sizes are traced, so steady-state compaction re-hits one compiled
    walk per bucket shape.  Walks segments k-1 .. 1 right-to-left; the
    state crossing a segment boundary is exactly the next segment's
    anchor, so the whole run is one ``fori_loop``.
    """
    nb_pad = (fusedS if bits else blocksS).shape[0] // k_pad
    n_bucket = nb_pad * r
    lanes = jnp.arange(k_pad, dtype=jnp.int32)
    active = lanes < k_actual
    anchor = lanes == k_actual - 1

    def symbol_at(seg, rank):
        blk = seg * nb_pad + rank // r
        if bits:
            return packed_symbol(fusedS, blk, rank % r,
                                 sigma=sigma, bits=bits)
        return blocksS[blk, rank % r]

    def record(ins, seg, I_vec):
        return ins.at[seg, I_vec[seg]].set(I_vec.sum() - I_vec[seg])

    # anchor: U's length-1 suffix (the last text's final character) sorts
    # before every longer suffix sharing its first character — in EVERY
    # segment's order at once
    seg0 = k_actual - 1
    I0 = jnp.where(active, c_mat[lanes, last_vec[seg0]], 0)
    ins0 = record(jnp.zeros((k_pad, n_bucket), jnp.int32), seg0, I0)
    pos0 = n_vec[seg0] - 1

    def body(_, state):
        I_vec, seg, pos, ins = state
        boundary = pos == 0
        # the symbol to prepend: within a segment, its own BWT at the
        # self rank; at a boundary, the PREVIOUS segment's last character
        c = jnp.where(
            boundary, last_vec[seg - 1],
            jnp.clip(symbol_at(seg, I_vec[seg]), 0, sigma - 1),
        )
        # per-walker wrap corrections, all from PRE-update states: drop
        # the bogus cyclic entry stored at row_j, add segment j's final
        # suffix iff its continuation (segment j+1's first suffix; for
        # the anchor lane, nothing) precedes the current suffix
        cmp = (row_vec < I_vec).astype(jnp.int32)
        nxt = jnp.where(anchor, 1, jnp.roll(cmp, -1))
        corr = jnp.where(last_vec == c, nxt - cmp, 0)
        blk = jnp.minimum(I_vec // r, nb_vec - 1)
        occ = ops.rank_walkers(
            fusedS, blocksS, occS, lanes * nb_pad + blk,
            jnp.full((k_pad,), c, jnp.int32), I_vec - blk * r,
            bits=bits, sigma=sigma,
        )
        I_new = jnp.where(active, c_mat[lanes, c] + occ + corr, 0)
        seg_new = jnp.where(boundary, seg - 1, seg)
        pos_new = jnp.where(boundary, n_vec[seg - 1] - 1, pos - 1)
        return I_new, seg_new, pos_new, record(ins, seg_new, I_new)

    n_walk = jnp.where(active & (lanes >= 1), n_vec, 0).sum()
    _, _, _, ins = lax.fori_loop(
        0, n_walk - 1, body, (I0, seg0, pos0, ins0)
    )
    return ins


def merge_kway(
    fms: list[FMIndex], *, compress_sa: bool | None = None,
    pack: bool | None = None,
) -> FMIndex:
    """BWT of ``T_1 ··· T_k`` spliced from the k built indexes — one
    rank-directed interleave walk, no sort, no intermediate accumulators.

    PRECONDITION (not checkable from the indexes alone): every operand but
    the last is *context-order safe* against the concatenation following
    it (``context_order_safe``; single prepared documents always are — the
    generalization that lifts the pairwise "multi-document texts only on
    the RIGHT" restriction).  ``kway_eligible`` must have returned None.
    The first operand is never walked (``segments._plan_run`` puts the
    largest there); all others LF-step right-to-left in one chained pass.
    Bit-identical to ``build_index_prepared`` on the same concatenation,
    and to the pairwise fold at k = 2.
    """
    reason = kway_eligible(fms)
    if reason:
        raise ValueError(f"cannot merge: {reason}")
    k = len(fms)
    f0 = fms[0]
    r, sigma, bits = f0.sample_rate, f0.sigma, f0.bits
    srate = f0.sa_sample_rate
    k_pad = _next_pow2(k)
    fused, blocks, occ, c_mat, nb_vec, _ = stack_rank_arrays(
        fms, seg_pad=k_pad
    )
    lens = [fm.length for fm in fms]
    pad = [0] * (k_pad - k)
    rows = [int(fm.row) for fm in fms]
    lasts = [int(np.asarray(fm.bwt)[rows[i]]) for i, fm in enumerate(fms)]
    ins = np.asarray(_kway_walk(
        fused, blocks, occ, c_mat, nb_vec,
        jnp.asarray(np.array(rows + pad, np.int32)),
        jnp.asarray(np.array(lasts + pad, np.int32)),
        jnp.asarray(np.array(lens + pad, np.int32)),
        jnp.asarray(k, jnp.int32),
        sigma=sigma, bits=bits, r=r, k_pad=k_pad,
    )).astype(np.int64)
    # a crash here leaves the operands untouched and no merged index —
    # same recovery contract as the pairwise ``merge.mid`` point
    fault_point("merge.kway")
    fault_point("merge.mid")

    # one-pass splice: walked rows land at ins[s, row] + row, the first
    # segment's rows fill the complement in order; then the chained wrap
    # exchange — each segment's suffix-0 cell holds the char preceding it
    # in U, i.e. the PREVIOUS segment's last char (U's own last char, the
    # cyclic wrap, for segment 0)
    N = sum(lens)
    offs = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    merged = np.empty(N, np.int32)
    is_walked = np.zeros(N, bool)
    pos = [None] * k
    for s in range(1, k):
        ps = ins[s, : lens[s]] + np.arange(lens[s])
        pos[s] = ps
        is_walked[ps] = True
        merged[ps] = np.asarray(fms[s].bwt)[: lens[s]]
    pos[0] = np.nonzero(~is_walked)[0]
    merged[pos[0]] = np.asarray(f0.bwt)[: lens[0]]
    for s in range(k):
        merged[pos[s][rows[s]]] = lasts[(s - 1) % k]

    sa_samples = None
    if srate:
        rows_m = np.concatenate([
            pos[s][sample_marked_rows(fms[s])] for s in range(k)
        ])
        vals_m = np.concatenate([
            decode_sa_values(fms[s]) + offs[s] for s in range(k)
        ]).astype(np.int32)
        order = np.argsort(rows_m, kind="stable")
        sa_samples = sample_arrays_from_rows(
            rows_m[order], vals_m[order], N, srate, compress=compress_sa,
        )

    return build_fm_index(
        jnp.asarray(merged), jnp.asarray(pos[0][rows[0]], jnp.int32),
        sigma, r, pack=bool(bits) if pack is None else pack,
        sa_samples=sa_samples, sa_sample_rate=srate,
    )
