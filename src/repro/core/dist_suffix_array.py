"""Distributed prefix-doubling suffix array + BWT (the paper's contribution).

The Spark pipeline of §2.2 mapped onto a TPU mesh axis (DESIGN.md §2):

    Init       histogram via psum + exclusive cumsum (Occ), local rank lookup
    Shift      ``shift_sharded`` (two static ppermutes instead of a keyed join)
    Pair+Sort  distributed sort of (rank, rank[i+h]) with index payload
               — engine 'bitonic' (deterministic) or 'samplesort' (the
               paper's range shuffle)
    Re-rank    boundary halo + local prefix-max + distributed exclusive max
    Scatter    route new ranks back to index order (sort-by-permutation or
               capacity-bounded all_to_all)
    Iterate    h <- 2h, unrolled (static ppermute perms), each round guarded
               by ``lax.cond`` on the all-distinct flag so converged inputs
               skip the collective work.

Everything here runs INSIDE ``shard_map``; ``build_isa_sharded`` /
``build_bwt_sharded`` are the jit-able host-level entry points.  The
doubling state (rank, done) is exposed so the driver can checkpoint the
loop at any round boundary (fault tolerance — DESIGN.md §7).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map

from .dist_sort import (
    ShardInfo,
    bitonic_sort_sharded,
    exclusive_max_sharded,
    exclusive_scan_sharded,
    samplesort_sharded,
    scatter_to_index_bitonic,
    scatter_to_index_samplesort,
    shift_sharded,
)
from .suffix_array import OVERFLOW_RANK

BITONIC = "bitonic"
SAMPLESORT = "samplesort"


class DistSAConfig(NamedTuple):
    axis: str = "parts"
    engine: str = BITONIC
    capacity_factor: float = 2.0   # samplesort bucket slack (Spark skew knob)
    rounds: int | None = None      # default ceil(log2 n)


def _gidx(info: ShardInfo) -> jax.Array:
    return lax.axis_index(info.axis) * info.part_size + jnp.arange(
        info.part_size, dtype=jnp.int32
    )


def dist_initial_ranks(info: ShardInfo, s_local: jax.Array, sigma: int) -> jax.Array:
    """Paper's Init: global char histogram (map/reduce == psum of local
    bincounts), exclusive cumsum = Occ, local lookup."""
    counts = lax.psum(jnp.bincount(s_local, length=sigma), info.axis)
    occ = jnp.cumsum(counts) - counts
    return occ[s_local].astype(jnp.int32)


def dist_rerank(
    info: ShardInfo,
    r1s: jax.Array,
    r2s: jax.Array,
    n_valid: jax.Array,
):
    """Paper's Re-Ranking on the globally sorted pair sequence.

    Valid slots are a prefix of each local shard (engines guarantee this);
    global position of local valid slot p = (# valid on earlier devices) + p.
    Returns (ranks_for_valid_slots, all_distinct).
    """
    slots = r1s.shape[0]
    pos = jnp.arange(slots, dtype=jnp.int32)
    valid = pos < n_valid
    offset = exclusive_scan_sharded(info, n_valid)
    gpos = offset + pos

    # previous device's last valid pair (halo for the boundary comparison)
    has_any = n_valid > 0
    last = jnp.maximum(n_valid - 1, 0)
    lastk = jnp.stack([r1s[last], r2s[last]])
    g_last = lax.all_gather(lastk, info.axis)          # (P, 2)
    g_has = lax.all_gather(has_any, info.axis)         # (P,)
    me = lax.axis_index(info.axis)
    jidx = jnp.arange(info.parts)
    prev_mask = (jidx < me) & g_has
    prev_exists = jnp.any(prev_mask)
    prev_j = jnp.argmax(jnp.where(prev_mask, jidx, -1))
    prev_k = g_last[prev_j]                            # (2,)

    prev1 = jnp.concatenate([prev_k[:1], r1s[:-1]])
    prev2 = jnp.concatenate([prev_k[1:], r2s[:-1]])
    neq = (r1s != prev1) | (r2s != prev2)
    # first global element has no predecessor -> always a group head
    neq = neq.at[0].set(jnp.where(prev_exists, neq[0], True))

    heads = jnp.where(valid & neq, gpos, -1)
    local_scan = lax.associative_scan(jnp.maximum, heads)
    carry = exclusive_max_sharded(info, local_scan[-1], identity=-1)
    ranks = jnp.maximum(local_scan, carry)

    n = info.n
    distinct = lax.psum(jnp.sum((valid & neq).astype(jnp.int32)), info.axis)
    return ranks.astype(jnp.int32), distinct == n


def _doubling_round(info: ShardInfo, cfg: DistSAConfig, h: int, rank, gidx):
    """One prefix-doubling round; returns (new_rank, all_distinct)."""
    r2 = shift_sharded(info, rank, h, OVERFLOW_RANK)

    if cfg.engine == BITONIC:
        r1s, r2s, idxs = bitonic_sort_sharded(info, (rank, r2, gidx), num_keys=2)
        n_valid = jnp.int32(info.part_size)
        new_sorted, done = dist_rerank(info, r1s, r2s, n_valid)
        (new_rank,) = scatter_to_index_bitonic(info, idxs, (new_sorted,))
        return new_rank, done

    res = samplesort_sharded(
        info, (rank, r2, gidx), num_keys=2, capacity_factor=cfg.capacity_factor
    )
    r1s, r2s, idxs = res.operands
    new_sorted, done = dist_rerank(info, r1s, r2s, res.n_valid)
    pos = jnp.arange(r1s.shape[0], dtype=jnp.int32)
    (new_rank,), overflow2 = scatter_to_index_samplesort(
        info, idxs, (new_sorted,), valid=pos < res.n_valid,
        capacity_factor=cfg.capacity_factor,
    )
    # overflow poisons the result with a recognizable sentinel; the host
    # driver checks ``isa_overflowed`` and retries with a larger factor
    bad = res.overflow | overflow2
    new_rank = jnp.where(bad, jnp.int32(-2), new_rank)
    return new_rank, done | bad


def num_rounds(n: int) -> int:
    return max(1, math.ceil(math.log2(max(2, n))))


def dist_isa_local(
    info: ShardInfo, cfg: DistSAConfig, s_local: jax.Array, sigma: int
) -> jax.Array:
    """shard_map body: local shard of S -> local shard of the ISA."""
    rank = dist_initial_ranks(info, s_local, sigma)
    gidx = _gidx(info)
    done = jnp.asarray(info.n <= 1)
    rounds = cfg.rounds if cfg.rounds is not None else num_rounds(info.n)
    for r in range(rounds):
        h = 2 ** r

        def do(args):
            rank, _ = args
            return _doubling_round(info, cfg, h, rank, gidx)

        rank, done = lax.cond(done, lambda a: a, do, (rank, done))
    return rank


def dist_bwt_local(
    info: ShardInfo, cfg: DistSAConfig, s_local: jax.Array, isa_local: jax.Array
):
    """shard_map body: (S, ISA) -> (SA, BWT, row) local shards.

    The paper's "join": bwt[i] = S[(SA[i]-1) mod n].  Routing steps (all
    permutations, so the bitonic engine is always exact here):
      1. SA[isa[i]] = i           (scatter by rank)
      2. fetch c[i] = S[SA[i]-1]  (scatter query to owner, answer in place)
      3. scatter answers back by output position
    """
    gidx = _gidx(info)
    n = info.n
    # 1. SA in index order
    (sa_local,) = scatter_to_index_bitonic(info, isa_local, (gidx,))
    # 2. j = (SA-1) mod n; route (j, out_pos) to the owner of j
    j = jnp.mod(sa_local - 1, n)
    j_sorted, outpos = bitonic_sort_sharded(info, (j, gidx), num_keys=1)
    # j is a permutation -> after sorting, local j's are exactly my range
    chars = s_local[j_sorted - lax.axis_index(info.axis) * info.part_size]
    # 3. route chars to their output position
    (bwt_local,) = scatter_to_index_bitonic(info, outpos, (chars,))
    row = lax.psum(jnp.sum(jnp.where(sa_local == 0, gidx, 0)), info.axis)
    return sa_local, bwt_local, row.astype(jnp.int32)


# ---------------------------------------------------------------------------
# host-level entry points (jit + shard_map over a 1-D mesh axis)
# ---------------------------------------------------------------------------

def isa_overflowed(isa) -> bool:
    """True when a samplesort round overflowed its capacity bound."""
    return bool(jnp.any(isa == -2))


@functools.partial(
    jax.jit, static_argnames=("sigma", "cfg", "mesh_axis_size", "mesh")
)
def _isa_jit(s, sigma, cfg, mesh_axis_size, mesh):
    info = ShardInfo(cfg.axis, mesh_axis_size, s.shape[0] // mesh_axis_size)
    fn = functools.partial(dist_isa_local, info, cfg, sigma=sigma)
    return shard_map(
        fn, mesh=mesh, in_specs=P(cfg.axis), out_specs=P(cfg.axis)
    )(s)


def build_isa_sharded(s, mesh: Mesh, cfg: DistSAConfig = DistSAConfig(), *, sigma: int):
    """Distributed ISA of a sentinel-terminated token string.

    ``len(s)`` must be divisible by the mesh axis size (pad upstream with
    trailing sentinels is NOT valid — the sentinel must be unique; instead
    the data pipeline pads with distinct high tokens, see data/corpus.py).
    """
    axis_size = mesh.shape[cfg.axis]
    if s.shape[0] % axis_size:
        raise ValueError(f"n={s.shape[0]} not divisible by axis size {axis_size}")
    s = jax.device_put(s, NamedSharding(mesh, P(cfg.axis)))
    return _isa_jit(s, sigma, cfg, axis_size, mesh)


@functools.partial(jax.jit, static_argnames=("cfg", "mesh_axis_size", "mesh"))
def _bwt_jit(s, isa, cfg, mesh_axis_size, mesh):
    info = ShardInfo(cfg.axis, mesh_axis_size, s.shape[0] // mesh_axis_size)
    fn = functools.partial(dist_bwt_local, info, cfg)
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(cfg.axis), P(cfg.axis)),
        out_specs=(P(cfg.axis), P(cfg.axis), P()),
    )(s, isa)


def build_bwt_sharded(s, mesh: Mesh, cfg: DistSAConfig = DistSAConfig(), *, sigma: int):
    """Distributed (SA, BWT, row) of a sentinel-terminated token string."""
    isa = build_isa_sharded(s, mesh, cfg, sigma=sigma)
    axis_size = mesh.shape[cfg.axis]
    s = jax.device_put(s, NamedSharding(mesh, P(cfg.axis)))
    return _bwt_jit(s, isa, cfg, axis_size, mesh)
