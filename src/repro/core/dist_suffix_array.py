"""Distributed prefix-doubling suffix array + BWT (the paper's contribution).

The Spark pipeline of §2.2 mapped onto a TPU mesh axis (DESIGN.md §2), with
the PR-2 build-engine optimisations (fused keys / q-gram init / discarding):

    Init       packed q-gram ranking: the first q = words * floor(32/ceil
               (log2 sigma)) characters of every suffix packed into 1-2
               uint32 words (q ppermute shifts), one distributed sort, and
               a grouped re-rank — replaces the seed's single-char Occ init
               AND the first ceil(log2 q) doubling rounds (3-5 rounds on
               the paper's corpora; single-device builds measure 2.3-2.6x
               end-to-end vs the seed on CPU).  The seed histogram init
               (`dist_initial_ranks`) remains behind ``qgram=False``.
    Shift      ``shift_sharded`` (two static ppermutes instead of a keyed join)
    Pair+Sort  each (rank, rank[i+h]) pair packs into one fused uint32 key
               word (two for n > 65535; ``core.keypack``), so the engines
               move one or two uint32 keys + an int32 index instead of three
               int32 operands — engine 'bitonic' (deterministic) or
               'samplesort' (the paper's range shuffle); local sorts
               dispatch to lax.sort or the Pallas LSD radix engine
               (``local_sort`` knob).
    Re-rank    grouped form: new_rank = rank + (pair-run head - rank-run
               head), boundary halos + local prefix-max + distributed
               exclusive max.  Identical to the paper's head-position rank
               when every suffix is active, and correct under discarding.
    Discard    a suffix whose rank is unique never re-sorts: its key becomes
               a pad, samplesort's capacity-bounded all_to_all skips pad
               slots entirely (shuffle volume tracks the active fraction;
               the bitonic engine keeps fixed buffers and gains nothing),
               and re-ranking touches only the shrinking active set.
    Scatter    route new ranks + active flags back to index order
               (sort-by-permutation or capacity-bounded all_to_all)
    Iterate    h <- q, 2q, 4q, ... unrolled (static ppermute perms), each
               round guarded by ``lax.cond`` on the no-actives-left flag.

Everything here runs INSIDE ``shard_map``; ``build_isa_sharded`` /
``build_bwt_sharded`` are the jit-able host-level entry points.  The
doubling state (rank, done) is exposed so the driver can checkpoint the
loop at any round boundary (fault tolerance — DESIGN.md §7).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map

from . import keypack
from .dist_sort import (
    ShardInfo,
    bitonic_sort_sharded,
    exclusive_max_sharded,
    exclusive_scan_sharded,
    samplesort_sharded,
    scatter_to_index_bitonic,
    scatter_to_index_samplesort,
    shift_sharded,
)
from .suffix_array import OVERFLOW_RANK, resolve_local_sort

BITONIC = "bitonic"
SAMPLESORT = "samplesort"


class DistSAConfig(NamedTuple):
    axis: str = "parts"
    engine: str = BITONIC
    capacity_factor: float = 2.0   # samplesort bucket slack (Spark skew knob)
    rounds: int | None = None      # default ceil(log2 (n / h0))
    qgram: bool = True             # packed q-gram init (False: seed Occ init)
    qgram_words: int = 2           # uint32 words per init key (64-bit logical)
    discard: bool = True           # drop unique-rank suffixes from the loop
    local_sort: str = "auto"       # "compare" | "radix" | "auto" (radix on TPU)


def _gidx(info: ShardInfo) -> jax.Array:
    return lax.axis_index(info.axis) * info.part_size + jnp.arange(
        info.part_size, dtype=jnp.int32
    )


def dist_initial_ranks(info: ShardInfo, s_local: jax.Array, sigma: int):
    """Seed Init: global char histogram (map/reduce == psum of local
    bincounts), exclusive cumsum = Occ, local lookup.  Also returns the
    active flags (char occurs more than once) for the discarding loop."""
    counts = lax.psum(jnp.bincount(s_local, length=sigma), info.axis)
    occ = jnp.cumsum(counts) - counts
    return occ[s_local].astype(jnp.int32), counts[s_local] > 1


def dist_rerank(
    info: ShardInfo,
    cols,
    n_valid: jax.Array,
    *,
    grouped: bool = False,
    want_active: bool = False,
):
    """Paper's Re-Ranking on the globally sorted (active) sequence.

    ``cols`` is a tuple of same-dtype sorted column arrays whose valid
    slots form a prefix of each local shard (engines guarantee this);
    global position of local valid slot p = (# valid on earlier devices)
    + p.  Group heads are found with a one-element halo from the previous
    non-empty device.

    * ``grouped=False``: rank = global head position of the equal-group —
      the paper's re-rank, used for the init sort.
    * ``grouped=True`` (``cols = (rank, rank2)``): rank = cols[0] +
      (pair-run head pos - rank-run head pos).  Because every rank is the
      head position of its rank-group (invariant of both inits, preserved
      here) and any group of size >= 2 is entirely active and contiguous in
      the sorted active sequence, this equals the head position the full
      re-rank would assign — while only ever looking at active suffixes.
    * ``want_active``: additionally return "my pair-group has size >= 2"
      flags (needs a successor halo: the first valid pair of the next
      non-empty device).

    Returns ``(ranks, active)``; ``active`` is None unless requested.
    """
    cols = tuple(cols)
    slots = cols[0].shape[0]
    pos = jnp.arange(slots, dtype=jnp.int32)
    valid = pos < n_valid
    offset = exclusive_scan_sharded(info, n_valid)
    gpos = offset + pos

    # previous device's last valid tuple (halo for the boundary comparison)
    has_any = n_valid > 0
    last = jnp.maximum(n_valid - 1, 0)
    lastk = jnp.stack([c[last] for c in cols])
    g_last = lax.all_gather(lastk, info.axis)          # (P, K)
    g_has = lax.all_gather(has_any, info.axis)         # (P,)
    me = lax.axis_index(info.axis)
    jidx = jnp.arange(info.parts)
    prev_mask = (jidx < me) & g_has
    prev_exists = jnp.any(prev_mask)
    prev_j = jnp.argmax(jnp.where(prev_mask, jidx, -1))
    prev_k = g_last[prev_j]                            # (K,)

    prevs = [
        jnp.concatenate([prev_k[i][None], c[:-1]]) for i, c in enumerate(cols)
    ]
    neq0 = cols[0] != prevs[0]
    neq_pair = neq0
    for c, pv in zip(cols[1:], prevs[1:]):
        neq_pair = neq_pair | (c != pv)
    # first global element has no predecessor -> always a group head
    neq0 = neq0.at[0].set(jnp.where(prev_exists, neq0[0], True))
    neq_pair = neq_pair.at[0].set(jnp.where(prev_exists, neq_pair[0], True))

    def head_pos(heads):
        local = lax.associative_scan(jnp.maximum, jnp.where(heads, gpos, -1))
        carry = exclusive_max_sharded(info, local[-1], identity=-1)
        return jnp.maximum(local, carry)

    pair_head = valid & neq_pair
    pair_pos = head_pos(pair_head)
    if grouped:
        col0_pos = head_pos(valid & neq0)
        ranks = (cols[0].astype(jnp.int32) + (pair_pos - col0_pos)).astype(
            jnp.int32
        )
    else:
        ranks = pair_pos.astype(jnp.int32)
    if not want_active:
        return ranks, None

    # successor halo: first valid tuple of the next non-empty device
    firstk = jnp.stack([c[0] for c in cols])
    g_first = lax.all_gather(firstk, info.axis)        # (P, K)
    next_mask = (jidx > me) & g_has
    next_j = jnp.argmax(next_mask)                     # first True (or 0)
    next_k = g_first[next_j]

    total = lax.psum(n_valid, info.axis)
    in_shard = pos + 1 < n_valid
    neq_succ = jnp.zeros(slots, bool)
    for i, c in enumerate(cols):
        succ = jnp.where(in_shard, jnp.roll(c, -1), next_k[i])
        neq_succ = neq_succ | (c != succ)
    is_glast = gpos == total - 1                       # no successor at all
    active = valid & ~(pair_head & (neq_succ | is_glast))
    return ranks, active


def dist_qgram_init(info: ShardInfo, cfg: DistSAConfig, eng: str,
                    s_local: jax.Array, sigma: int):
    """Packed q-gram init: rank every suffix by its first q characters in
    one distributed sort.  Returns (rank, active, q, overflow)."""
    q, fpw, bits = keypack.qgram_params(sigma, cfg.qgram_words)
    m = info.part_size
    if q - 1 <= m:
        # all q windows are local given a (q-1)-char halo from the next
        # device: ONE small ppermute instead of q-1 full-shard shifts
        if q > 1:
            perm = [(i, (i - 1) % info.parts) for i in range(info.parts)]
            halo = lax.ppermute(s_local[: q - 1], info.axis, perm)
            # past the global end the window reuses the sentinel value 0
            halo = jnp.where(
                lax.axis_index(info.axis) == info.parts - 1, 0, halo
            )
            ext = jnp.concatenate([s_local, halo])
        else:
            ext = s_local
        chars = [ext[j: j + m] for j in range(q)]
    else:
        # tiny shards (m < q - 1): fall back to iterated distributed shifts
        chars = [s_local]
        for _ in range(q - 1):
            chars.append(shift_sharded(info, chars[-1], 1, 0))
    words = []
    for w in range(cfg.qgram_words):
        v = jnp.zeros_like(s_local, dtype=jnp.uint32)
        for j in range(w * fpw, (w + 1) * fpw):
            v = (v << bits) | chars[j].astype(jnp.uint32)
        words.append(v)
    gidx = _gidx(info)
    nw = cfg.qgram_words
    kb = (min(32, fpw * bits),) * nw

    if cfg.engine == BITONIC:
        sorted_ops = bitonic_sort_sharded(
            info, (*words, gidx), num_keys=nw, local_sort=eng, key_bits=kb
        )
        ranks_s, active_s = dist_rerank(
            info, sorted_ops[:nw], jnp.int32(info.part_size),
            grouped=False, want_active=True,
        )
        rank, act = scatter_to_index_bitonic(
            info, sorted_ops[nw], (ranks_s, active_s.astype(jnp.int32)),
            local_sort=eng,
        )
        return rank, act.astype(bool), q, jnp.asarray(False)

    pads = (keypack.qgram_pad(fpw, bits),) * nw
    res = samplesort_sharded(
        info, (*words, gidx), num_keys=nw,
        capacity_factor=cfg.capacity_factor, key_pads=pads,
        local_sort=eng, key_bits=kb,
    )
    ranks_s, active_s = dist_rerank(
        info, res.operands[:nw], res.n_valid, grouped=False, want_active=True
    )
    pos = jnp.arange(res.operands[0].shape[0], dtype=jnp.int32)
    (rank, act), ovf = scatter_to_index_samplesort(
        info, res.operands[nw], (ranks_s, active_s.astype(jnp.int32)),
        valid=pos < res.n_valid, capacity_factor=cfg.capacity_factor,
    )
    bad = res.overflow | ovf
    rank = jnp.where(bad, jnp.int32(-2), rank)
    return rank, act.astype(bool), q, bad


def _doubling_round(info: ShardInfo, cfg: DistSAConfig, eng: str,
                    spec: keypack.PairSpec, h: int, rank, gidx, active):
    """One fused-key prefix-doubling round over the active suffixes;
    returns (new_rank, new_active, done)."""
    r2 = shift_sharded(info, rank, h, OVERFLOW_RANK)
    words = keypack.pack_pairs(rank, r2, spec)
    pads = spec.pad_words()
    kb = spec.key_bits
    W = spec.words
    if cfg.discard:
        # unique-rank suffixes become pad slots: they sort last and (with
        # samplesort) never enter the all_to_all
        words = tuple(
            jnp.where(active, w, jnp.uint32(p)) for w, p in zip(words, pads)
        )

    if cfg.engine == BITONIC:
        sorted_ops = bitonic_sort_sharded(
            info, (*words, gidx), num_keys=W, local_sort=eng, key_bits=kb
        )
        r1s, r2s = keypack.unpack_pairs(sorted_ops[:W], spec)
        idxs = sorted_ops[W]
        if cfg.discard:
            # pads sort after every real pair key, so the global active
            # prefix maps to per-device valid prefixes
            n_act = lax.psum(jnp.sum(active.astype(jnp.int32)), info.axis)
            me = lax.axis_index(info.axis)
            n_valid = jnp.clip(
                n_act - me * info.part_size, 0, info.part_size
            ).astype(jnp.int32)
        else:
            n_valid = jnp.int32(info.part_size)
        ranks_s, active_s = dist_rerank(
            info, (r1s, r2s), n_valid, grouped=True, want_active=True
        )
        pos = jnp.arange(r1s.shape[0], dtype=jnp.int32)
        valid_s = pos < n_valid
        vr = jnp.where(valid_s, ranks_s, 0)
        va = jnp.where(valid_s, 1 + active_s.astype(jnp.int32), 0)
        nr, na = scatter_to_index_bitonic(info, idxs, (vr, va), local_sort=eng)
        bad = jnp.asarray(False)
    else:
        n_valid_in = (
            jnp.sum(active.astype(jnp.int32)) if cfg.discard else None
        )
        res = samplesort_sharded(
            info, (*words, gidx), num_keys=W,
            capacity_factor=cfg.capacity_factor, key_pads=pads,
            n_valid_in=n_valid_in, local_sort=eng, key_bits=kb,
        )
        r1s, r2s = keypack.unpack_pairs(res.operands[:W], spec)
        idxs = res.operands[W]
        ranks_s, active_s = dist_rerank(
            info, (r1s, r2s), res.n_valid, grouped=True, want_active=True
        )
        pos = jnp.arange(r1s.shape[0], dtype=jnp.int32)
        valid_s = pos < res.n_valid
        vr = jnp.where(valid_s, ranks_s, 0)
        va = jnp.where(valid_s, 1 + active_s.astype(jnp.int32), 0)
        (nr, na), ovf = scatter_to_index_samplesort(
            info, idxs, (vr, va), valid=valid_s,
            capacity_factor=cfg.capacity_factor,
        )
        bad = res.overflow | ovf

    # va encodes per-index outcome: 0 untouched (stays final), 1 became
    # unique, 2 still ambiguous
    new_rank = jnp.where(na > 0, nr, rank)
    new_active = jnp.where(na > 0, na == 2, active)
    # overflow poisons the result with a recognizable sentinel; the host
    # driver checks ``isa_overflowed`` and retries with a larger factor
    new_rank = jnp.where(bad, jnp.int32(-2), new_rank)
    remaining = lax.psum(jnp.sum(new_active.astype(jnp.int32)), info.axis)
    return new_rank, new_active, (remaining == 0) | bad


def num_rounds(n: int, h0: int = 1) -> int:
    """Doubling rounds to cover length n starting from pairing distance
    h0: smallest r with h0 * 2^r >= n."""
    if n <= max(1, h0):
        return 0
    return max(1, math.ceil(math.log2(n / h0)))


def dist_isa_local(
    info: ShardInfo, cfg: DistSAConfig, s_local: jax.Array, sigma: int
) -> jax.Array:
    """shard_map body: local shard of S -> local shard of the ISA."""
    if cfg.qgram and info.n > 1:
        eng = resolve_local_sort(cfg.local_sort)
        rank, active, h0, bad = dist_qgram_init(info, cfg, eng, s_local, sigma)
    else:
        eng = resolve_local_sort(cfg.local_sort)
        rank, active = dist_initial_ranks(info, s_local, sigma)
        h0, bad = 1, jnp.asarray(False)
    gidx = _gidx(info)
    spec = keypack.pair_spec(info.n)
    remaining = lax.psum(jnp.sum(active.astype(jnp.int32)), info.axis)
    done = jnp.asarray(info.n <= 1) | (remaining == 0) | bad
    rounds = cfg.rounds if cfg.rounds is not None else num_rounds(info.n, h0)
    for r in range(rounds):
        h = h0 * (2 ** r)

        def do(args, h=h):
            rank, active, done = args
            return _doubling_round(info, cfg, eng, spec, h, rank, gidx, active)

        rank, active, done = lax.cond(
            done, lambda a: a, do, (rank, active, done)
        )
    return rank


def dist_bwt_local(
    info: ShardInfo, cfg: DistSAConfig, s_local: jax.Array, isa_local: jax.Array
):
    """shard_map body: (S, ISA) -> (SA, BWT, row) local shards.

    The paper's "join": bwt[i] = S[(SA[i]-1) mod n].  Routing steps (all
    permutations, so the bitonic engine is always exact here):
      1. SA[isa[i]] = i           (scatter by rank)
      2. fetch c[i] = S[SA[i]-1]  (scatter query to owner, answer in place)
      3. scatter answers back by output position
    """
    gidx = _gidx(info)
    n = info.n
    # 1. SA in index order
    (sa_local,) = scatter_to_index_bitonic(info, isa_local, (gidx,))
    # 2. j = (SA-1) mod n; route (j, out_pos) to the owner of j
    j = jnp.mod(sa_local - 1, n)
    j_sorted, outpos = bitonic_sort_sharded(info, (j, gidx), num_keys=1)
    # j is a permutation -> after sorting, local j's are exactly my range
    chars = s_local[j_sorted - lax.axis_index(info.axis) * info.part_size]
    # 3. route chars to their output position
    (bwt_local,) = scatter_to_index_bitonic(info, outpos, (chars,))
    row = lax.psum(jnp.sum(jnp.where(sa_local == 0, gidx, 0)), info.axis)
    return sa_local, bwt_local, row.astype(jnp.int32)


# ---------------------------------------------------------------------------
# host-level entry points (jit + shard_map over a 1-D mesh axis)
# ---------------------------------------------------------------------------

def isa_overflowed(isa) -> bool:
    """True when a samplesort round overflowed its capacity bound."""
    return bool(jnp.any(isa == -2))


@functools.partial(
    jax.jit, static_argnames=("sigma", "cfg", "mesh_axis_size", "mesh")
)
def _isa_jit(s, sigma, cfg, mesh_axis_size, mesh):
    info = ShardInfo(cfg.axis, mesh_axis_size, s.shape[0] // mesh_axis_size)
    fn = functools.partial(dist_isa_local, info, cfg, sigma=sigma)
    return shard_map(
        fn, mesh=mesh, in_specs=P(cfg.axis), out_specs=P(cfg.axis)
    )(s)


def build_isa_sharded(s, mesh: Mesh, cfg: DistSAConfig = DistSAConfig(), *, sigma: int):
    """Distributed ISA of a sentinel-terminated token string.

    ``len(s)`` must be divisible by the mesh axis size (pad upstream with
    trailing sentinels is NOT valid — the sentinel must be unique; instead
    the data pipeline pads with distinct high tokens, see data/corpus.py).
    """
    axis_size = mesh.shape[cfg.axis]
    if s.shape[0] % axis_size:
        raise ValueError(f"n={s.shape[0]} not divisible by axis size {axis_size}")
    s = jax.device_put(s, NamedSharding(mesh, P(cfg.axis)))
    return _isa_jit(s, sigma, cfg, axis_size, mesh)


@functools.partial(jax.jit, static_argnames=("cfg", "mesh_axis_size", "mesh"))
def _bwt_jit(s, isa, cfg, mesh_axis_size, mesh):
    info = ShardInfo(cfg.axis, mesh_axis_size, s.shape[0] // mesh_axis_size)
    fn = functools.partial(dist_bwt_local, info, cfg)
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(cfg.axis), P(cfg.axis)),
        out_specs=(P(cfg.axis), P(cfg.axis), P()),
    )(s, isa)


def build_bwt_sharded(s, mesh: Mesh, cfg: DistSAConfig = DistSAConfig(), *, sigma: int):
    """Distributed (SA, BWT, row) of a sentinel-terminated token string."""
    isa = build_isa_sharded(s, mesh, cfg, sigma=sigma)
    axis_size = mesh.shape[cfg.axis]
    s = jax.device_put(s, NamedSharding(mesh, P(cfg.axis)))
    return _bwt_jit(s, isa, cfg, axis_size, mesh)
