"""Single-device suffix-array construction by prefix doubling.

This is the reference implementation of the paper's algorithm (§2.2):

    Init      rank[i] = Occ(S(i))          (count of strictly-smaller chars)
    Pair      pair rank[i] with rank[i+h]  (overflow pairs with a value that
                                            compares below every real rank)
    Re-rank   sort pairs, new rank = position of the head of the equal-group
    Iterate   h <- 2h, until all ranks distinct (<= ceil(log2 n) rounds)

Everything is a fixed-shape jittable program: the doubling loop is a
``lax.while_loop`` with an early-exit condition on rank distinctness, so the
compiled artifact is shape-stable while still stopping after the data-
dependent number of rounds the paper describes.

The distributed version (``dist_suffix_array.py``) reuses ``rerank_from_sorted``
semantics shard-by-shard; this module doubles as its oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

OVERFLOW_RANK = -1  # shorter suffix sorts first; real ranks are >= 0


def initial_ranks(s: jax.Array, sigma: int) -> jax.Array:
    """Paper's Init step: rank[i] = Occ(S(i)) via histogram + exclusive
    cumulative sum (the map/reduce + local scan of §2.2)."""
    counts = jnp.bincount(s, length=sigma)
    occ = jnp.cumsum(counts) - counts  # exclusive prefix sum == Occ(c)
    return occ[s].astype(jnp.int32)


def rerank_from_sorted(
    r1_sorted: jax.Array, r2_sorted: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Paper's Re-rank step, applied to lexicographically sorted pairs.

    new_rank[i] = i                 if pair[i] != pair[i-1]
                = new_rank[i-1]     otherwise
    which equals a prefix-max over ``i * [pair changed at i]``.

    Returns ``(new_ranks, all_distinct)``; ``all_distinct`` is true when every
    sorted pair differs from its predecessor (termination condition).
    """
    n = r1_sorted.shape[0]
    neq = (r1_sorted[1:] != r1_sorted[:-1]) | (r2_sorted[1:] != r2_sorted[:-1])
    flags = jnp.concatenate([jnp.ones((1,), dtype=bool), neq])
    heads = jnp.where(flags, jnp.arange(n, dtype=jnp.int32), 0)
    return lax.associative_scan(jnp.maximum, heads), jnp.all(flags)


def shifted_ranks(rank: jax.Array, h: jax.Array) -> jax.Array:
    """rank2[i] = rank[i+h] for i+h < n else OVERFLOW_RANK (paper's Shifting
    and Pairing, expressed as a roll + mask instead of a keyed join)."""
    n = rank.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    rolled = jnp.roll(rank, -h)
    return jnp.where(idx + h < n, rolled, OVERFLOW_RANK).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("sigma",))
def isa_prefix_doubling(s: jax.Array, sigma: int) -> jax.Array:
    """Compute the inverse suffix array (ISA: suffix index -> rank) of ``s``.

    ``s`` must terminate with the unique smallest sentinel (token 0); see
    ``alphabet.append_sentinel``.
    """
    n = s.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    rank0 = initial_ranks(s, sigma)

    def cond(state):
        _, h, done = state
        return (h < n) & ~done

    def body(state):
        rank, h, _ = state
        r2 = shifted_ranks(rank, h)
        r1s, r2s, perm = lax.sort((rank, r2, idx), num_keys=2)
        new_sorted, done = rerank_from_sorted(r1s, r2s)
        new_rank = jnp.zeros_like(rank).at[perm].set(new_sorted)
        return new_rank, h * 2, done

    # the sentinel makes n == 1 trivially done; otherwise at least one round
    rank, _, _ = lax.while_loop(
        cond, body, (rank0, jnp.int32(1), jnp.asarray(n == 1))
    )
    return rank


def sa_from_isa(isa: jax.Array) -> jax.Array:
    """SA[rank] = i  (inversion of a permutation)."""
    n = isa.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    return jnp.zeros_like(isa).at[isa].set(idx)


@functools.partial(jax.jit, static_argnames=("sigma",))
def suffix_array(s: jax.Array, sigma: int) -> jax.Array:
    """Suffix array of a sentinel-terminated token string."""
    return sa_from_isa(isa_prefix_doubling(s, sigma))


def suffix_array_naive(s) -> "np.ndarray":  # noqa: F821 - numpy oracle
    """O(n^2 log n) numpy oracle for tests."""
    import numpy as np

    s = np.asarray(s)
    n = len(s)
    suffixes = sorted(range(n), key=lambda i: s[i:].tolist())
    return np.array(suffixes, dtype=np.int32)
