"""Single-device suffix-array construction by prefix doubling.

``isa_prefix_doubling`` is the reference implementation of the paper's
algorithm (§2.2) and the bit-for-bit oracle for every faster path:

    Init      rank[i] = Occ(S(i))          (count of strictly-smaller chars)
    Pair      pair rank[i] with rank[i+h]  (overflow pairs with a value that
                                            compares below every real rank)
    Re-rank   sort pairs, new rank = position of the head of the equal-group
    Iterate   h <- 2h, until all ranks distinct (<= ceil(log2 n) rounds)

``build_isa_fast`` / ``suffix_array_fast`` are the production build engine
(same output, asserted bit-for-bit by tests/test_build_fast.py), with three
hot-loop optimisations the reference deliberately omits:

* **Fused pair keys** — each (rank, rank[i+h]) pair packs into one uint32
  word (two for n > 65535) via ``core.keypack``, so the sort moves 2
  operands instead of 3 and the radix engine knows the significant key bits.
* **Packed q-gram init** — initial ranks come from the first
  q = words * floor(32 / ceil(log2 sigma)) characters packed into 1-2
  uint32 words (two words by default: 20 chars for the sigma=6 DNA
  corpora), so the loop starts at h=q and skips the first ceil(log2 q)
  doubling rounds (measured on the 64 Ki corpora: 5 of 16 rounds skipped
  for DNA and ZERO rounds left to run — the init resolves every suffix;
  english still runs 2 rounds over a 44%-then-3.5% active set).
* **Active-suffix discarding** — a suffix whose rank is unique never
  changes rank again; each round partition-compacts the still-ambiguous
  suffixes into a geometrically shrinking capacity bucket (host-driven, one
  compile per power-of-two capacity) and sorts only those.  Re-ranking uses
  the grouped form ``new_rank = r1 + (pair_head_pos - r1_head_pos)``, which
  reduces to the paper's head-position rank when everything is active.

Local sorts dispatch through ``kernels.ops.radix_sort`` (Pallas LSD radix
on TPU, jnp counting sort fallback) or ``lax.sort``, selected by the
``local_sort`` knob ("auto" picks radix on TPU, compare elsewhere — the
jnp counting sort loses to XLA's native sort on CPU by ~3x).

Measured end-to-end (benchmarks/table2_bwt.py, one CPU core, 64 Ki
corpora): 2.3-2.6x vs the seed single-jit builder and 2.7-3.8x vs the
Menon et al. competitor, identical BWT output everywhere.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import keypack
from ..kernels import ops as kernel_ops

OVERFLOW_RANK = -1  # shorter suffix sorts first; real ranks are >= 0


def initial_ranks(s: jax.Array, sigma: int) -> jax.Array:
    """Paper's Init step: rank[i] = Occ(S(i)) via histogram + exclusive
    cumulative sum (the map/reduce + local scan of §2.2)."""
    counts = jnp.bincount(s, length=sigma)
    occ = jnp.cumsum(counts) - counts  # exclusive prefix sum == Occ(c)
    return occ[s].astype(jnp.int32)


def rerank_from_sorted(
    r1_sorted: jax.Array, r2_sorted: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Paper's Re-rank step, applied to lexicographically sorted pairs.

    new_rank[i] = i                 if pair[i] != pair[i-1]
                = new_rank[i-1]     otherwise
    which equals a prefix-max over ``i * [pair changed at i]``.

    Returns ``(new_ranks, all_distinct)``; ``all_distinct`` is true when every
    sorted pair differs from its predecessor (termination condition).
    """
    n = r1_sorted.shape[0]
    neq = (r1_sorted[1:] != r1_sorted[:-1]) | (r2_sorted[1:] != r2_sorted[:-1])
    flags = jnp.concatenate([jnp.ones((1,), dtype=bool), neq])
    heads = jnp.where(flags, jnp.arange(n, dtype=jnp.int32), 0)
    return lax.associative_scan(jnp.maximum, heads), jnp.all(flags)


def shifted_ranks(rank: jax.Array, h: jax.Array) -> jax.Array:
    """rank2[i] = rank[i+h] for i+h < n else OVERFLOW_RANK (paper's Shifting
    and Pairing, expressed as a roll + mask instead of a keyed join)."""
    n = rank.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    rolled = jnp.roll(rank, -h)
    return jnp.where(idx + h < n, rolled, OVERFLOW_RANK).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("sigma",))
def isa_prefix_doubling(s: jax.Array, sigma: int) -> jax.Array:
    """Compute the inverse suffix array (ISA: suffix index -> rank) of ``s``.

    ``s`` must terminate with the unique smallest sentinel (token 0); see
    ``alphabet.append_sentinel``.
    """
    n = s.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    rank0 = initial_ranks(s, sigma)

    def cond(state):
        _, h, done = state
        return (h < n) & ~done

    def body(state):
        rank, h, _ = state
        r2 = shifted_ranks(rank, h)
        r1s, r2s, perm = lax.sort((rank, r2, idx), num_keys=2)
        new_sorted, done = rerank_from_sorted(r1s, r2s)
        new_rank = jnp.zeros_like(rank).at[perm].set(new_sorted)
        return new_rank, h * 2, done

    # the sentinel makes n == 1 trivially done; otherwise at least one round
    rank, _, _ = lax.while_loop(
        cond, body, (rank0, jnp.int32(1), jnp.asarray(n == 1))
    )
    return rank


def sa_from_isa(isa: jax.Array) -> jax.Array:
    """SA[rank] = i  (inversion of a permutation)."""
    n = isa.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    return jnp.zeros_like(isa).at[isa].set(idx)


@functools.partial(jax.jit, static_argnames=("sigma",))
def suffix_array(s: jax.Array, sigma: int) -> jax.Array:
    """Suffix array of a sentinel-terminated token string."""
    return sa_from_isa(isa_prefix_doubling(s, sigma))


# ---------------------------------------------------------------------------
# fast build engine: fused keys + packed q-gram init + discarding
# ---------------------------------------------------------------------------

# engine dispatch lives in kernels.ops (single implementation, shared with
# the distributed sort engines in dist_sort.py)
from ..kernels.ops import (  # noqa: E402  (re-export)
    COMPARE,
    RADIX,
    resolve_sort_engine as resolve_local_sort,
)


@dataclasses.dataclass
class BuildStats:
    """Machine-readable build trajectory (feeds BENCH_build.json)."""

    n: int
    sigma: int
    q: int                       # packed chars in the init key (1 = Occ init)
    h0: int                      # first pairing distance (q, or 1)
    rounds_executed: int = 0
    rounds_skipped: int = 0      # h=1.. doubling rounds the q-gram init skips
    active_frac: list = dataclasses.field(default_factory=list)
    local_sort: str = COMPARE
    discard: bool = True

    def as_dict(self):
        return dataclasses.asdict(self)


@functools.partial(jax.jit, static_argnames=("fpw", "bits", "words", "engine"))
def _qgram_init(s, fpw: int, bits: int, words: int, engine: str):
    """Initial (rank, active) from the packed q-gram key of every suffix:
    one q-gram key sort + grouped re-rank instead of ceil(log2 q) doubling
    rounds.  rank = head position of the key-equal group (the same
    invariant the Occ init establishes); active = group size > 1."""
    n = s.shape[0]
    keys = keypack.qgram_keys_local(s, fpw, bits, words)
    kb = (min(32, fpw * bits),) * words
    idx = jnp.arange(n, dtype=jnp.int32)
    sorted_ops = kernel_ops.local_sort(
        (*keys, idx), words, engine=engine, key_bits=kb
    )
    ks, perm = sorted_ops[:words], sorted_ops[words]
    neq = jnp.zeros(n - 1, bool)
    for k in ks:
        neq = neq | (k[1:] != k[:-1])
    head = jnp.concatenate([jnp.ones(1, bool), neq])
    ranks_sorted = lax.associative_scan(
        jnp.maximum, jnp.where(head, idx, 0)
    ).astype(jnp.int32)
    succ_head = jnp.concatenate([head[1:], jnp.ones(1, bool)])
    active_sorted = ~(head & succ_head)
    rank = jnp.zeros(n, jnp.int32).at[perm].set(ranks_sorted)
    active = jnp.zeros(n, bool).at[perm].set(active_sorted)
    return rank, active


@functools.partial(jax.jit, static_argnames=("sigma",))
def _occ_init(s, sigma: int):
    """Seed Occ init + active flags (char occurs more than once)."""
    counts = jnp.bincount(s, length=sigma)
    occ = jnp.cumsum(counts) - counts
    return occ[s].astype(jnp.int32), counts[s] > 1


@functools.lru_cache(maxsize=None)
def _fast_round(n: int, cap: int, engine: str):
    """One fused-key doubling round over the compacted active set.

    Static in (n, cap, engine) — the host loop shrinks cap geometrically,
    so at most log2(n) variants compile; h and n_active are traced.
    Grouped re-rank: every rank is the global head position of its equal
    group (invariant from both inits and preserved below), a size->=2 group
    is entirely active, and its active members are contiguous in the sorted
    active sequence — so
        new_rank = r1 + (pair_subrun_head_pos - r1_run_head_pos)
    equals the head position the full re-rank would assign.
    """
    spec = keypack.pair_spec(n)
    pads = spec.pad_words()
    kb = spec.key_bits
    W = spec.words

    @jax.jit
    def step(rank, active_idx, n_active, h):
        slot = jnp.arange(cap, dtype=jnp.int32)
        valid = slot < n_active
        ai = jnp.where(valid, active_idx, 0)
        r1 = rank[ai]
        tgt = ai + h
        r2 = jnp.where(tgt < n, rank[jnp.minimum(tgt, n - 1)], OVERFLOW_RANK)
        words = keypack.pack_pairs(r1, r2, spec)
        words = tuple(
            jnp.where(valid, w, jnp.uint32(p)) for w, p in zip(words, pads)
        )
        sorted_ops = kernel_ops.local_sort(
            (*words, ai), W, engine=engine, key_bits=kb
        )
        r1s, r2s = keypack.unpack_pairs(sorted_ops[:W], spec)
        ais = sorted_ops[W]

        valid_s = slot < n_active   # pads sort strictly last (keypack proof)
        neq1 = jnp.concatenate([jnp.ones(1, bool), r1s[1:] != r1s[:-1]])
        neq2 = jnp.concatenate([jnp.ones(1, bool), r2s[1:] != r2s[:-1]])
        r1_head = valid_s & neq1
        pair_head = valid_s & (neq1 | neq2)
        r1_pos = lax.associative_scan(
            jnp.maximum, jnp.where(r1_head, slot, -1))
        pair_pos = lax.associative_scan(
            jnp.maximum, jnp.where(pair_head, slot, -1))
        new_rank = r1s + (pair_pos - r1_pos)

        succ_head = (
            jnp.concatenate([pair_head[1:], jnp.zeros(1, bool)])
            | (slot + 1 >= n_active)
        )
        still = valid_s & ~(pair_head & succ_head)

        scatter_idx = jnp.where(valid_s, ais, n)
        rank = rank.at[scatter_idx].set(new_rank, mode="drop")
        (keep_pos,) = jnp.nonzero(still, size=cap, fill_value=cap)
        new_active = jnp.where(
            keep_pos < cap, ais[jnp.minimum(keep_pos, cap - 1)], n
        )
        return rank, new_active, jnp.sum(still.astype(jnp.int32))

    return step


def _cap_bucket(n_active: int, n: int, min_cap: int = 128) -> int:
    """Next power-of-two capacity (floored) for the compacted active set."""
    return min(n, max(min_cap, 1 << max(0, n_active - 1).bit_length()))


def build_isa_fast(
    s,
    sigma: int,
    *,
    local_sort: str = "auto",
    qgram: bool = True,
    qgram_words: int = 2,
    discard: bool = True,
):
    """ISA of a sentinel-terminated token string via the fused-key engine.

    Host-driven round loop (reads back the active count each round to pick
    the next capacity bucket); bit-for-bit identical to
    ``isa_prefix_doubling``.  Returns ``(isa, BuildStats)``.
    """
    s = jnp.asarray(s, jnp.int32)
    n = s.shape[0]
    engine = resolve_local_sort(local_sort)
    if qgram and n > 1:
        q, fpw, bits = keypack.qgram_params(sigma, qgram_words)
        rank, active = _qgram_init(s, fpw, bits, qgram_words, engine)
        h = q
        skipped = keypack.qgram_rounds_skipped(q)
    else:
        q, h, skipped = 1, 1, 0
        rank, active = _occ_init(s, sigma)
    stats = BuildStats(n=n, sigma=sigma, q=q, h0=h, rounds_skipped=skipped,
                       local_sort=engine, discard=discard)
    if n <= 1:
        return rank, stats

    if discard:
        (active_pos,) = jnp.nonzero(active, size=n, fill_value=n)
        n_active = int(jnp.sum(active))
        cap = _cap_bucket(n_active, n)
        active_buf = active_pos[:cap].astype(jnp.int32)
    else:
        n_active = n if bool(jnp.any(active)) else 0
        cap = n
        active_buf = jnp.arange(n, dtype=jnp.int32)

    while n_active > 0:
        assert h < 2 * n, "prefix doubling failed to converge (bad sentinel?)"
        stats.active_frac.append(n_active / n)
        step = _fast_round(n, cap, engine)
        rank, new_buf, n_active_dev = step(
            rank, active_buf, jnp.int32(n_active), jnp.int32(h)
        )
        stats.rounds_executed += 1
        h *= 2
        remaining = int(n_active_dev)
        if discard:
            n_active = remaining
            new_cap = _cap_bucket(n_active, n)
            active_buf = new_buf[:new_cap] if new_cap < cap else new_buf
            cap = min(cap, new_cap)
        else:
            n_active = n if remaining else 0
    return rank, stats


def suffix_array_fast(s, sigma: int, **kwargs):
    """(SA, BuildStats) via the fused-key build engine."""
    isa, stats = build_isa_fast(s, sigma, **kwargs)
    return sa_from_isa(isa), stats


def suffix_array_naive(s) -> "np.ndarray":  # noqa: F821 - numpy oracle
    """O(n^2 log n) numpy oracle for tests."""
    import numpy as np

    s = np.asarray(s)
    n = len(s)
    suffixes = sorted(range(n), key=lambda i: s[i:].tolist())
    return np.array(suffixes, dtype=np.int32)
