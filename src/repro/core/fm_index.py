"""FM-index over a BWT: C array, Occ checkpoints, backward search, locate.

This is the "full-text index that enables fast querying" the paper builds
toward (§1): exact pattern matching in O(m) rank queries per pattern,
independent of the indexed-text length, plus occurrence localisation via a
sampled suffix array.

Layout (all dense arrays, shard- and jit-friendly):

* ``bwt``          int32[n_blocks * r]  last column, PAD beyond position n
* ``C``            int32[sigma]  # chars strictly smaller (exclusive cumsum)
* ``occ_samples``  int32[n_blocks + 1, sigma]  checkpointed exclusive Occ
* ``fused``        int32[n_blocks, sigma + r/fpw]  (small alphabets only)
  per-block [Occ checkpoint | bit-packed words] — the interleaved succinct
  layout the Pallas rank kernel consumes (kernels/rank_select.py)
* ``sa_marks/sa_mark_ranks/sa_vals``  SA sample for locate(): rows whose SA
  value is a multiple of ``sa_sample_rate`` are marked in a bitvector (with
  per-word popcount checkpoints) and their values stored in row order; any
  occurrence is recovered by LF-walking <= sa_sample_rate - 1 steps to a
  marked row.  The stored values are optionally *compressed*: every marked
  value is a multiple of the stride s, so ``val // s`` fits in
  ``ceil(log2(n / s))`` bits and is bit-packed into a contiguous int32
  bitstream (``sa_val_bits`` > 0 selects the packed decode).  At small
  strides this shrinks the dominant locate structure ~2-3x (e.g. 32 -> 12
  bits per value for n = 2^16, s = 4).

rank(c, p) = occ_samples[p // r, c] + count of c in bwt[(p//r)*r : p].
``sample_rate`` trades memory for per-query scan length r — the classic
FM-index trade-off the paper cites ([4] Ferragina-Manzini).  The in-block
count is the hot spot; all query paths dispatch through ``kernels/ops``
(packed popcount Pallas kernel on TPU, vectorised jnp fallback elsewhere).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..kernels import ops
from ..kernels.rank_select import pack_words, packed_bits

PAD = -1  # query padding token


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FMIndex:
    bwt: jax.Array            # int32[n_blocks * r], PAD beyond position n
    row: jax.Array            # scalar int32: row of the original string
    c_array: jax.Array        # int32[sigma]
    occ_samples: jax.Array    # int32[n_blocks + 1, sigma]
    fused: jax.Array | None   # int32[n_blocks, sigma + W] packed layout
    sa_marks: jax.Array | None       # int32[ceil(n/32)] bitvector
    sa_mark_ranks: jax.Array | None  # int32[ceil(n/32)] excl. popcount cumsum
    sa_vals: jax.Array | None        # int32[#marked] SA values, row order
                                     # (or packed words when sa_val_bits > 0)
    sample_rate: int          # static (pytree aux data)
    sigma: int                # static (pytree aux data)
    length: int               # static: true text length n
    bits: int                 # static: packed field width (0 = unpacked)
    sa_sample_rate: int       # static: SA sampling stride (0 = no locate)
    sa_val_bits: int = 0      # static: bits per packed SA value (0 = raw)

    @property
    def n(self) -> int:
        return self.length

    @property
    def n_blocks(self) -> int:
        return self.occ_samples.shape[0] - 1

    def tree_flatten(self):
        return (
            (self.bwt, self.row, self.c_array, self.occ_samples, self.fused,
             self.sa_marks, self.sa_mark_ranks, self.sa_vals),
            (self.sample_rate, self.sigma, self.length, self.bits,
             self.sa_sample_rate, self.sa_val_bits),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def pack_sa_values(q: np.ndarray, bits: int) -> np.ndarray:
    """Bit-pack int values ``q`` (each < 2^bits, bits < 32) LSB-first into a
    contiguous int32 bitstream; value i occupies bits [i*bits, (i+1)*bits).

    One trailing guard word is appended so the two-word decode in
    ``unpack_sa_value`` never reads out of bounds.  Host-side numpy.
    """
    q = np.asarray(q, np.uint64)
    n = q.size
    bitpos = np.arange(n, dtype=np.int64) * bits
    w = bitpos >> 5
    off = (bitpos & 31).astype(np.uint64)
    nwords = int(-(-(n * bits) // 32)) + 1  # ceil + guard word
    words = np.zeros(nwords, np.uint64)
    lo = q << off                       # spans <= 2 consecutive 32-bit words
    np.bitwise_or.at(words, w, lo & np.uint64(0xFFFFFFFF))
    np.bitwise_or.at(words, w + 1, lo >> np.uint64(32))
    return words.astype(np.uint32).view(np.int32)


def unpack_sa_value(words: jax.Array, idx: jax.Array, bits: int) -> jax.Array:
    """Decode packed value ``idx`` from a ``pack_sa_values`` bitstream.

    ``words`` int32[nwords], ``idx`` int32[B] (any shape), ``bits`` static.
    Two gathers + shifts per value; out-of-range idx (garbage lanes of the
    locate walk) clamp in bounds and decode garbage, exactly like the raw
    ``vals[clip(idx)]`` path.
    """
    W = lax.bitcast_convert_type(words, jnp.uint32)
    # idx * bits can overflow int32 at corpus scale; split the product
    base = (idx // 32) * bits
    rem = (idx % 32) * bits
    w = jnp.clip(base + rem // 32, 0, words.shape[0] - 2)
    off = (rem % 32).astype(jnp.uint32)
    lo = W[w] >> off
    hi = jnp.where(
        off > 0,
        W[w + 1] << ((jnp.uint32(32) - off) & jnp.uint32(31)),
        jnp.uint32(0),
    )
    mask = jnp.uint32((1 << bits) - 1)
    return ((lo | hi) & mask).astype(jnp.int32)


def sample_arrays_from_rows(rows, vals, n: int, sa_sample_rate: int, *,
                            compress: bool | None = None):
    """(marks, mark_ranks, vals, val_bits) from an explicit marked-row set.

    ``rows``: sorted row indices whose SA value is a multiple of the
    stride; ``vals``: those values in the same (row) order; ``n``: index
    length.  The single constructor of the on-index SA-sample arrays,
    shared by ``build_sa_samples`` (rows derived from a full SA) and the
    BWT-merge path (rows spliced from two merged indexes) — so both
    produce bit-identical arrays, including the ``compress`` decision,
    for the same marked set.
    """
    idx = np.asarray(rows, np.int64)
    vals = np.asarray(vals, np.int32)
    nwords = -(-n // 32)
    words = np.zeros(nwords, np.uint32)
    np.bitwise_or.at(
        words, idx // 32, np.uint32(1) << (idx % 32).astype(np.uint32)
    )
    pc = np.unpackbits(words.view(np.uint8)).reshape(nwords, 32).sum(axis=1)
    ranks = (np.cumsum(pc) - pc).astype(np.int32)
    q = vals // sa_sample_rate             # exact: marked vals are multiples
    val_bits = max(1, int(q.max()).bit_length()) if q.size else 0
    if compress is None:
        compress = 0 < val_bits < 32
    if compress and not 0 < val_bits < 32:
        raise ValueError(f"cannot compress SA sample (val_bits={val_bits})")
    if not compress:
        val_bits = 0
    return (
        jnp.asarray(words.view(np.int32)),
        jnp.asarray(ranks),
        jnp.asarray(pack_sa_values(q, val_bits) if compress else vals),
        val_bits,
    )


def build_sa_samples(sa, sa_sample_rate: int, *, compress: bool | None = None):
    """(marks, mark_ranks, vals, val_bits) for locate(): host-side, exact.

    Rows i with SA[i] % s == 0 are marked; their SA values are stored in row
    order.  Value lookup for marked row i is vals[mark_ranks[i//32] +
    popcount(marks[i//32] & low_bits(i%32))] — O(1), fully vectorisable.

    ``compress`` bit-packs the stored values: every sampled value is a
    multiple of s, so ``val // s`` fits ``ceil(log2(n/s))`` bits.  None
    (default) packs whenever that width beats raw int32; the returned
    ``val_bits`` (0 = raw) selects the decode in ``sample_lookup``.
    """
    sa_np = np.asarray(sa)
    marked = (sa_np % sa_sample_rate) == 0
    # SA holds 0, so the marked set is never empty
    return sample_arrays_from_rows(
        np.nonzero(marked)[0], sa_np[marked].astype(np.int32),
        sa_np.shape[0], sa_sample_rate, compress=compress,
    )


def decode_sa_values(fm) -> np.ndarray:
    """Raw int32 SA-sample values of an index in row order (host-side),
    undoing the optional bit-packing.  The sampled values are exactly
    {0, s, 2s, ...} below the text length, so the count is implied."""
    nvals = -(-fm.length // fm.sa_sample_rate)
    if fm.sa_val_bits:
        return np.asarray(unpack_sa_value(
            fm.sa_vals, jnp.arange(nvals, dtype=jnp.int32), fm.sa_val_bits,
        )) * fm.sa_sample_rate
    return np.asarray(fm.sa_vals)[:nvals]


def sample_marked_rows(fm) -> np.ndarray:
    """Sorted row indices carrying an SA sample (host-side): the set bits
    of the ``sa_marks`` bitvector below the text length."""
    words = np.asarray(fm.sa_marks).view(np.uint32)
    bits = np.unpackbits(words.view(np.uint8), bitorder="little")
    return np.nonzero(bits[: fm.length])[0]


FM_ARRAY_FIELDS = ("bwt", "row", "c_array", "occ_samples", "fused",
                   "sa_marks", "sa_mark_ranks", "sa_vals")
FM_AUX_FIELDS = ("sample_rate", "sigma", "length", "bits",
                 "sa_sample_rate", "sa_val_bits")


def fm_mismatch(a: FMIndex, b: FMIndex) -> list:
    """Field names on which two FM-indexes differ (empty = bit-identical).

    The single bit-identity oracle behind every merge-vs-rebuild parity
    assertion (fuzz suite, dist driver, compaction benchmark) — one field
    list, so a new ``FMIndex`` field cannot silently fall out of parity
    coverage."""
    out = [name for name in FM_AUX_FIELDS
           if getattr(a, name) != getattr(b, name)]
    for name in FM_ARRAY_FIELDS:
        x, y = getattr(a, name), getattr(b, name)
        if (x is None) != (y is None):
            out.append(name)
        elif x is not None and not np.array_equal(np.asarray(x),
                                                  np.asarray(y)):
            out.append(name)
    return out


def build_fm_index(
    bwt_arr: jax.Array, row: jax.Array, sigma: int, sample_rate: int = 64,
    *, sa: jax.Array | None = None, sa_sample_rate: int = 32,
    pack: bool | None = None, compress_sa: bool | None = None,
    sa_samples: tuple | None = None,
) -> FMIndex:
    """Build the query index from a BWT.

    ``bwt_arr`` int32[n] (tokens in [0, sigma)), ``row`` scalar int32 (the
    BWT row of the original string), ``sample_rate`` the Occ checkpoint
    spacing r.  ``pack=None`` bit-packs whenever the alphabet fits (sigma <=
    16 and r divisible by the fields-per-word); ``pack=False`` forces the
    unpacked layout (benchmark baseline).  Passing the suffix array ``sa``
    enables ``locate`` via SA sampling; ``compress_sa`` as in
    ``build_sa_samples``.  ``sa_samples`` = (marks, mark_ranks, vals,
    val_bits) injects prebuilt sample arrays instead (checkpoint restore,
    where the full SA no longer exists).
    """
    n = bwt_arr.shape[0]
    counts = jnp.bincount(bwt_arr, length=sigma)
    c_array = (jnp.cumsum(counts) - counts).astype(jnp.int32)

    n_blocks = -(-n // sample_rate)  # ceil
    pad = n_blocks * sample_rate - n
    padded = jnp.pad(bwt_arr, (0, pad), constant_values=PAD)
    onehot = (padded[:, None] == jnp.arange(sigma)[None, :]).astype(jnp.int32)
    block_counts = onehot.reshape(n_blocks, sample_rate, sigma).sum(axis=1)
    occ_samples = jnp.concatenate(
        [jnp.zeros((1, sigma), jnp.int32), jnp.cumsum(block_counts, axis=0)]
    )  # exclusive checkpoints: occ_samples[k] counts bwt[: k*r]

    bits = 0 if pack is False else packed_bits(sigma, sample_rate)
    if pack and not bits:
        raise ValueError(
            f"cannot pack sigma={sigma} at sample_rate={sample_rate}"
        )
    fused = None
    if bits:
        words = pack_words(padded, bits).reshape(n_blocks, -1)
        fused = jnp.concatenate([occ_samples[:-1], words], axis=1)

    if sa_samples is not None:
        sa_marks, sa_mark_ranks, sa_vals, sa_val_bits = sa_samples
    elif sa is not None:
        sa_marks, sa_mark_ranks, sa_vals, sa_val_bits = build_sa_samples(
            sa, sa_sample_rate, compress=compress_sa
        )
    else:
        sa_marks = sa_mark_ranks = sa_vals = None
        sa_sample_rate = sa_val_bits = 0

    # the padded copy keeps every in-block dynamic_slice in bounds
    return FMIndex(padded, jnp.asarray(row, jnp.int32), c_array, occ_samples,
                   fused, sa_marks, sa_mark_ranks, sa_vals,
                   sample_rate, sigma, n, bits, sa_sample_rate, sa_val_bits)


def occ_batch(index: FMIndex, c: jax.Array, p: jax.Array) -> jax.Array:
    """# occurrences of c_i in ``bwt[:p_i]`` (exclusive rank), batched.

    Dispatches through kernels/ops: packed popcount rank when the index is
    bit-packed, batched unpacked gather otherwise.  p == n_blocks*r is
    folded into the last block (cutoff r) so checkpoints beyond the fused
    rows are never needed.
    """
    r = index.sample_rate
    blk = jnp.minimum(p // r, index.n_blocks - 1)
    cut = p - blk * r
    if index.bits:
        return ops.rank_packed(index.fused, blk, c, cut,
                               bits=index.bits, sigma=index.sigma)
    base = index.occ_samples[blk, c]
    blocks = index.bwt.reshape(index.n_blocks, r)
    return base + ops.rank_unpacked(blocks, blk, c, cut)


def occ(index: FMIndex, c: jax.Array, p: jax.Array) -> jax.Array:
    """Scalar Occ(c, p): int32 scalars in, int32 scalar out — convenience
    wrapper over the batched path (same kernel dispatch)."""
    return occ_batch(index, c[None] if c.ndim == 0 else c,
                     p[None] if p.ndim == 0 else p)[0]


def _interval_step(c, sp, ep, sigma: int, rank):
    """One backward-search transition, shared by the monolithic and the
    stacked (segment-parallel) paths — any divergence here would break
    their bit-identity.  ``rank(c_safe, p)`` maps a symbol/position pair to
    ``C[c] + Occ(c, p)``; all arrays are elementwise-broadcastable.

    PAD steps are no-ops; an already-empty interval stays empty; an
    out-of-alphabet symbol (unknown to the index) empties it."""
    in_alphabet = (c >= 1) & (c < sigma)
    valid = in_alphabet & (ep > sp)
    c_safe = jnp.where(in_alphabet, c, 0)
    nsp = rank(c_safe, sp)
    nep = rank(c_safe, ep)
    return (
        jnp.where(valid, nsp, sp),
        jnp.where(valid, nep, jnp.where((c != PAD) & ~in_alphabet, sp, ep)),
    )


def backward_search_batch(
    index: FMIndex, patterns: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(sp, ep) suffix-array intervals for int32[B, m] PAD-padded patterns.

    Count of exact occurrences is ``ep - sp``.  One scan step per pattern
    position; each step issues a single batched rank call per interval end,
    so the whole batch shares kernel launches.
    """
    B = patterns.shape[0]

    def rank(c, p):
        return index.c_array[c] + occ_batch(index, c, p)

    def step(state, c):
        return _interval_step(c, *state, index.sigma, rank), None

    # process right-to-left; PADs sit on the right so they come first and
    # are skipped by ``valid``
    init = (jnp.zeros(B, jnp.int32), jnp.full((B,), index.n, jnp.int32))
    (sp, ep), _ = lax.scan(step, init, patterns.T[::-1])
    return sp, ep


def backward_search(index: FMIndex, pattern: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-pattern (sp, ep) — batched path with B=1."""
    sp, ep = backward_search_batch(index, pattern[None, :])
    return sp[0], ep[0]


@jax.jit
def count(index: FMIndex, patterns: jax.Array) -> jax.Array:
    """Batched exact-match counts: patterns int32[B, m] PAD-padded (PAD =
    -1 on the right) -> counts int32[B].  One rank-kernel dispatch per
    pattern position and interval end (see ``occ_batch``), jit-cached per
    (B, m) shape."""
    sp, ep = backward_search_batch(index, patterns)
    return jnp.maximum(ep - sp, 0)


def sample_lookup(marks, mark_ranks, vals, rows, *, val_bits: int = 0,
                  val_scale: int = 1, idx_offset=0):
    """(marked, value) of the SA sample at each row (value garbage when
    unmarked).  Raw-array form shared with the distributed index and the
    stacked segment-parallel path.

    ``rows`` int32[B]; ``val_bits`` > 0 decodes the bit-packed value stream
    (value = packed quotient * ``val_scale``, the sampling stride); 0 reads
    raw int32 values.  ``idx_offset`` shifts the value-stream index (the
    stacked path concatenates per-segment value arrays and passes each
    lane's segment base).
    """
    w = rows // 32
    b = (rows % 32).astype(jnp.uint32)
    word = lax.bitcast_convert_type(marks[w], jnp.uint32)
    marked = ((word >> b) & jnp.uint32(1)).astype(bool)
    below = lax.population_count(
        word & ((jnp.uint32(1) << b) - jnp.uint32(1))
    )
    idx = mark_ranks[w] + below.astype(jnp.int32) + idx_offset
    if val_bits:
        val = unpack_sa_value(vals, idx, val_bits) * val_scale
    else:
        val = vals[jnp.clip(idx, 0, vals.shape[0] - 1)]
    return marked, val


def _sample_lookup(index: FMIndex, rows: jax.Array):
    return sample_lookup(index.sa_marks, index.sa_mark_ranks, index.sa_vals,
                         rows, val_bits=index.sa_val_bits,
                         val_scale=index.sa_sample_rate)


def packed_symbol(fused, blk, j, *, sigma: int, bits: int) -> jax.Array:
    """Decode symbol ``j`` of fused row ``blk`` from the packed words —
    the one packed-layout decode, shared by the monolithic and stacked
    paths."""
    fpw = 32 // bits
    word = fused[blk, sigma + j // fpw]
    w = lax.bitcast_convert_type(word, jnp.uint32)
    sh = ((j % fpw) * bits).astype(jnp.uint32)
    return ((w >> sh) & jnp.uint32((1 << bits) - 1)).astype(jnp.int32)


def bwt_symbol(index: FMIndex, rows: jax.Array) -> jax.Array:
    """bwt[rows] batched: rows int32[B] -> symbols int32[B] — extracted
    from packed words when bit-packed, so the locate walk touches only the
    compact layout."""
    if not index.bits:
        return index.bwt[rows]
    r = index.sample_rate
    return packed_symbol(index.fused, rows // r, rows % r,
                         sigma=index.sigma, bits=index.bits)


def _locate_walk(n_steps: int, rows, valid, lookup, lf_next):
    """The locate LF-walk, shared by the monolithic and stacked paths —
    any divergence here would break their bit-identity.  Each lane walks
    ``rows`` toward its nearest SA-sampled row: ``lookup(rows)`` ->
    (marked, sampled value), ``lf_next(rows)`` -> LF-mapped rows.  Returns
    flat positions (garbage where ``~valid``)."""

    def body(_, st):
        rows, pos, steps, done = st
        marked, val = lookup(rows)
        pos = jnp.where(marked & ~done, val + steps, pos)
        done = done | marked
        rows = jnp.where(done, rows, lf_next(rows))
        steps = steps + jnp.where(done, 0, 1)
        return rows, pos, steps, done

    zeros = jnp.zeros(rows.shape[0], jnp.int32)
    _, pos, _, _ = lax.fori_loop(
        0, n_steps, body, (rows, zeros, zeros, ~valid)
    )
    return pos


@functools.partial(jax.jit, static_argnames=("k",))
def locate(
    index: FMIndex, patterns: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """First-k occurrence positions per pattern via the SA sample.

    patterns int32[B, m] PAD-padded.  Returns (positions int32[B, k] sorted
    ascending with ``n`` filling unused slots, counts int32[B] clipped to k).
    Each of the B*k candidate rows LF-walks (<= sa_sample_rate - 1 steps,
    every step one batched rank call) to its nearest marked row; position =
    sampled value + steps walked.
    """
    if index.sa_sample_rate == 0:
        raise ValueError("index built without sa= — locate unavailable")
    sp, ep = backward_search_batch(index, patterns)
    B = sp.shape[0]
    rows = (sp[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :])
    valid = (rows < ep[:, None]).reshape(-1)
    rows = jnp.where(valid, rows.reshape(-1), 0)

    def lf_next(rows):
        c = bwt_symbol(index, rows)
        return index.c_array[c] + occ_batch(index, c, rows)

    pos = _locate_walk(index.sa_sample_rate, rows, valid,
                       lambda rows: _sample_lookup(index, rows), lf_next)
    out = jnp.where(valid, pos, index.n).reshape(B, k)
    return jnp.sort(out, axis=1), jnp.minimum(jnp.maximum(ep - sp, 0), k)


# -- segment-parallel stacked queries ----------------------------------------
#
# A SegmentedIndex answers a query by asking every live segment.  Done
# naively that is one jit dispatch per segment per backward-search step; the
# stacked layout below pads every segment's fused rows to one bucket shape
# (power-of-two block count) and concatenates them row-wise, so the whole
# catalog answers through a SINGLE kernels/ops rank call per step — the
# per-query work is identical element-wise to the sequential path, so the
# results are bit-identical (asserted in tests/test_segments.py).


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class StackedFMIndex:
    """S per-segment FM-indexes padded to one bucket shape.

    ``fused``/``blocks`` rows of all segments concatenate along axis 0
    (segment s owns rows [s*blocks_pad, s*blocks_pad + n_blocks[s])), so a
    flat query vector carrying a segment id per lane addresses the whole
    catalog in one gather.  Bucket shapes (``seg_pad`` segments x
    ``blocks_pad`` blocks, both powers of two) keep the jit cache stable as
    segments append and compact.  Pad segments have length 0 (their search
    interval starts empty) and pad blocks are never addressed (block ids
    clamp to the true per-segment ``n_blocks``).  SA-sample values are
    stored raw (packed streams are decoded at stack time) so one decode
    path serves every segment.
    """

    fused: jax.Array | None    # int32[S*NB, sigma + W]     (packed layout)
    blocks: jax.Array | None   # int32[S*NB, r]             (unpacked layout)
    occ: jax.Array | None      # int32[S, NB, sigma]        (unpacked layout)
    c_array: jax.Array         # int32[S, sigma]
    n_blocks: jax.Array        # int32[S] true per-segment block counts
    lengths: jax.Array         # int32[S] true per-segment text lengths
    sa_marks: jax.Array | None       # int32[S*MW] (segment-major)
    sa_mark_ranks: jax.Array | None  # int32[S*MW] per-segment cumsums
    sa_vals: jax.Array | None        # int32[S*MV] raw (decoded) SA values
    n_seg: jax.Array    # int32 scalar: real segment count (<= seg_pad) —
                        # a LEAF, not static aux: appending a segment into
                        # spare bucket capacity must not recompile
    seg_pad: int        # static: padded segment count S
    blocks_pad: int     # static: padded per-segment block count NB
    sample_rate: int    # static
    sigma: int          # static
    bits: int           # static
    sa_sample_rate: int  # static (0 = no locate)

    def tree_flatten(self):
        return (
            (self.fused, self.blocks, self.occ, self.c_array, self.n_blocks,
             self.lengths, self.sa_marks, self.sa_mark_ranks, self.sa_vals,
             self.n_seg),
            (self.seg_pad, self.blocks_pad, self.sample_rate,
             self.sigma, self.bits, self.sa_sample_rate),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def stack_fm_indexes(
    fms: list[FMIndex], *, seg_pad: int | None = None,
    blocks_pad: int | None = None,
) -> StackedFMIndex:
    """Assemble single-device FM-indexes into one stacked bucket layout.

    All indexes must agree on (sigma, sample_rate, bits, sa_sample_rate) —
    segments built through one ``SegmentedIndex`` do by construction (the
    declared alphabet reserves the pad slot, see ``pipeline.prepare_tokens``).
    Raises ``ValueError`` on a mixed catalog (e.g. segments restored from a
    pre-uniform-alphabet checkpoint); callers fall back to the sequential
    path.  ``seg_pad``/``blocks_pad`` override the power-of-two bucket
    defaults (must be >= the real sizes).
    """
    if not fms:
        raise ValueError("cannot stack an empty catalog")
    f0 = fms[0]
    sig = (f0.sigma, f0.sample_rate, f0.bits, f0.sa_sample_rate)
    for fm in fms:
        if not isinstance(fm, FMIndex):
            raise ValueError(f"cannot stack {type(fm).__name__}")
        if (fm.sigma, fm.sample_rate, fm.bits, fm.sa_sample_rate) != sig:
            raise ValueError(
                "mixed segment layouts: "
                f"{(fm.sigma, fm.sample_rate, fm.bits, fm.sa_sample_rate)} "
                f"!= {sig}"
            )
    sigma, r, bits, srate = sig
    S = seg_pad or _next_pow2(len(fms))
    NB = blocks_pad or _next_pow2(max(fm.n_blocks for fm in fms))
    if S < len(fms) or NB < max(fm.n_blocks for fm in fms):
        raise ValueError("bucket shape smaller than the catalog")

    fused = blocks = occ = None
    if bits:
        W = f0.fused.shape[1]
        fused_np = np.zeros((S * NB, W), np.int32)
        for i, fm in enumerate(fms):
            fused_np[i * NB : i * NB + fm.n_blocks] = np.asarray(fm.fused)
        fused = jnp.asarray(fused_np)
    else:
        blocks_np = np.full((S * NB, r), PAD, np.int32)
        occ_np = np.zeros((S, NB, sigma), np.int32)
        for i, fm in enumerate(fms):
            nb = fm.n_blocks
            blocks_np[i * NB : i * NB + nb] = (
                np.asarray(fm.bwt).reshape(nb, r)
            )
            occ_np[i, :nb] = np.asarray(fm.occ_samples)[:-1]
        blocks, occ = jnp.asarray(blocks_np), jnp.asarray(occ_np)

    c_np = np.zeros((S, sigma), np.int32)
    nb_np = np.ones(S, np.int32)       # pad segments clamp blk to 0
    len_np = np.zeros(S, np.int32)     # pad segments start with ep == 0
    for i, fm in enumerate(fms):
        c_np[i] = np.asarray(fm.c_array)
        nb_np[i] = fm.n_blocks
        len_np[i] = fm.length

    sa_marks = sa_mark_ranks = sa_vals = None
    if srate:
        MW = -(-(NB * r) // 32)
        MV = -(-(NB * r) // srate)
        marks_np = np.zeros((S, MW), np.int32)
        ranks_np = np.zeros((S, MW), np.int32)
        vals_np = np.zeros((S, MV), np.int32)
        for i, fm in enumerate(fms):
            m = np.asarray(fm.sa_marks)
            marks_np[i, : m.shape[0]] = m
            ranks_np[i, : m.shape[0]] = np.asarray(fm.sa_mark_ranks)
            raw = decode_sa_values(fm)
            vals_np[i, : raw.shape[0]] = raw
        sa_marks, sa_mark_ranks, sa_vals = (
            jnp.asarray(marks_np.reshape(-1)),
            jnp.asarray(ranks_np.reshape(-1)),
            jnp.asarray(vals_np.reshape(-1)),
        )

    return StackedFMIndex(
        fused, blocks, occ, jnp.asarray(c_np), jnp.asarray(nb_np),
        jnp.asarray(len_np), sa_marks, sa_mark_ranks, sa_vals,
        jnp.asarray(len(fms), jnp.int32), S, NB, r, sigma, bits, srate,
    )


def stack_rank_arrays(fms: list[FMIndex], *, seg_pad: int | None = None,
                      blocks_pad: int | None = None):
    """Bucket-stack the rank-addressable arrays of same-layout indexes:
    ``(fused, blocks, occ, c_mat, nb_vec, blocks_pad)`` with segment i
    owning block rows [i*blocks_pad, i*blocks_pad + n_blocks_i).

    The rank-only core of ``stack_fm_indexes`` (no SA-sample stacking),
    built for the k-way merge walk: one batched ``ops.rank_walkers``
    dispatch addresses every walker's segment through a single array, and
    the pow2 bucket (``seg_pad`` segments x ``blocks_pad`` blocks) keeps
    steady-state compactions re-hitting one compiled walk per shape.
    ``occ`` is flattened to int32[S*NB, sigma] so packed and unpacked
    layouts share the flat ``seg * blocks_pad + blk`` addressing."""
    if not fms:
        raise ValueError("cannot stack an empty run")
    f0 = fms[0]
    sig = (f0.sigma, f0.sample_rate, f0.bits)
    for fm in fms:
        if (fm.sigma, fm.sample_rate, fm.bits) != sig:
            raise ValueError(
                f"mixed layouts {(fm.sigma, fm.sample_rate, fm.bits)} "
                f"!= {sig}"
            )
    sigma, r, bits = sig
    S = seg_pad or _next_pow2(len(fms))
    NB = blocks_pad or _next_pow2(max(fm.n_blocks for fm in fms))
    if S < len(fms) or NB < max(fm.n_blocks for fm in fms):
        raise ValueError("bucket shape smaller than the run")
    fused = blocks = occ = None
    if bits:
        fused_np = np.zeros((S * NB, f0.fused.shape[1]), np.int32)
        for i, fm in enumerate(fms):
            fused_np[i * NB : i * NB + fm.n_blocks] = np.asarray(fm.fused)
        fused = jnp.asarray(fused_np)
    else:
        blocks_np = np.full((S * NB, r), PAD, np.int32)
        occ_np = np.zeros((S * NB, sigma), np.int32)
        for i, fm in enumerate(fms):
            nb = fm.n_blocks
            blocks_np[i * NB : i * NB + nb] = (
                np.asarray(fm.bwt).reshape(nb, r)
            )
            occ_np[i * NB : i * NB + nb] = np.asarray(fm.occ_samples)[:-1]
        blocks, occ = jnp.asarray(blocks_np), jnp.asarray(occ_np)
    c_np = np.zeros((S, sigma), np.int32)
    for i, fm in enumerate(fms):
        c_np[i] = np.asarray(fm.c_array)
    return fused, blocks, occ, jnp.asarray(c_np), jnp.asarray(
        np.array([fm.n_blocks for fm in fms] + [1] * (S - len(fms)),
                 np.int32)
    ), NB


def _stack_check(st: StackedFMIndex, fm: FMIndex) -> None:
    """Raise unless ``fm`` fits the stacked bucket layout (same static
    signature, block count within the bucket)."""
    if not isinstance(fm, FMIndex):
        raise ValueError(f"cannot stack {type(fm).__name__}")
    sig = (st.sigma, st.sample_rate, st.bits, st.sa_sample_rate)
    if (fm.sigma, fm.sample_rate, fm.bits, fm.sa_sample_rate) != sig:
        raise ValueError(
            "segment layout does not match the stacked catalog: "
            f"{(fm.sigma, fm.sample_rate, fm.bits, fm.sa_sample_rate)} "
            f"!= {sig}"
        )
    if fm.n_blocks > st.blocks_pad:
        raise ValueError(
            f"segment blocks {fm.n_blocks} exceed bucket {st.blocks_pad}"
        )


def _seg_rows(st: StackedFMIndex, fm: FMIndex):
    """One segment's per-leaf row payloads, padded to the bucket shapes —
    the update unit shared by ``stacked_append`` and ``stacked_replace``."""
    NB, r, sigma = st.blocks_pad, st.sample_rate, st.sigma
    out = {}
    if st.bits:
        rows = jnp.zeros((NB, st.fused.shape[1]), jnp.int32)
        out["fused"] = rows.at[: fm.n_blocks].set(fm.fused)
    else:
        rows = jnp.full((NB, r), PAD, jnp.int32)
        out["blocks"] = rows.at[: fm.n_blocks].set(
            fm.bwt.reshape(fm.n_blocks, r)
        )
        occ = jnp.zeros((NB, sigma), jnp.int32)
        out["occ"] = occ.at[: fm.n_blocks].set(fm.occ_samples[:-1])
    out["c_array"] = fm.c_array
    out["n_blocks"] = jnp.asarray(fm.n_blocks, jnp.int32)
    out["lengths"] = jnp.asarray(fm.length, jnp.int32)
    if st.sa_sample_rate:
        MW = st.sa_marks.shape[0] // st.seg_pad
        MV = st.sa_vals.shape[0] // st.seg_pad
        m = np.asarray(fm.sa_marks)
        marks = np.zeros(MW, np.int32)
        ranks = np.zeros(MW, np.int32)
        vals = np.zeros(MV, np.int32)
        marks[: m.shape[0]] = m
        ranks[: m.shape[0]] = np.asarray(fm.sa_mark_ranks)
        raw = decode_sa_values(fm)
        vals[: raw.shape[0]] = raw
        out["sa_marks"] = jnp.asarray(marks)
        out["sa_mark_ranks"] = jnp.asarray(ranks)
        out["sa_vals"] = jnp.asarray(vals)
    return out


def stacked_append(st: StackedFMIndex, fm: FMIndex) -> StackedFMIndex:
    """Append one segment into spare bucket capacity, in place.

    Writes the new segment's rows into slot ``n_seg`` of every leaf and
    bumps ``n_seg`` — all static shapes and aux data are unchanged, so the
    query jit programs compiled for the old catalog serve the new one
    without recompiling (``n_seg`` is a pytree leaf).  Raises ``ValueError``
    when the bucket is full or the segment does not fit; callers re-stack.
    """
    _stack_check(st, fm)
    i = int(st.n_seg)
    if i >= st.seg_pad:
        raise ValueError(f"stacked catalog full ({i} == seg_pad)")
    NB = st.blocks_pad
    rows = _seg_rows(st, fm)
    rep = {"n_seg": jnp.asarray(i + 1, jnp.int32)}
    for name in ("fused", "blocks"):
        if rows.get(name) is not None and getattr(st, name) is not None:
            rep[name] = getattr(st, name).at[i * NB : (i + 1) * NB].set(
                rows[name]
            )
    if not st.bits:
        rep["occ"] = st.occ.at[i].set(rows["occ"])
    rep["c_array"] = st.c_array.at[i].set(rows["c_array"])
    rep["n_blocks"] = st.n_blocks.at[i].set(rows["n_blocks"])
    rep["lengths"] = st.lengths.at[i].set(rows["lengths"])
    if st.sa_sample_rate:
        MW = st.sa_marks.shape[0] // st.seg_pad
        MV = st.sa_vals.shape[0] // st.seg_pad
        rep["sa_marks"] = st.sa_marks.at[i * MW : (i + 1) * MW].set(
            rows["sa_marks"]
        )
        rep["sa_mark_ranks"] = st.sa_mark_ranks.at[
            i * MW : (i + 1) * MW
        ].set(rows["sa_mark_ranks"])
        rep["sa_vals"] = st.sa_vals.at[i * MV : (i + 1) * MV].set(
            rows["sa_vals"]
        )
    return dataclasses.replace(st, **rep)


def stacked_replace_run(st: StackedFMIndex, start: int, count: int,
                        fm: FMIndex) -> StackedFMIndex:
    """Replace segments [start, start+count) with one merged segment.

    The incremental stacked-catalog update after a merge compaction:
    later segments shift left on-device (concatenation of existing leaf
    slices — no host re-assembly of the whole catalog), bucket shapes stay
    fixed, so steady-state compaction re-hits the same query jit programs.
    Raises ``ValueError`` when the merged segment does not fit the bucket.
    """
    _stack_check(st, fm)
    n = int(st.n_seg)
    if not (0 <= start and count >= 1 and start + count <= n):
        raise ValueError(f"bad run [{start}, {start + count}) of {n}")
    rows = _seg_rows(st, fm)
    n_new = n - count + 1
    S = st.seg_pad

    def splice(arr, unit, new_rows, fill):
        head = arr[: (start + 1) * unit].at[
            start * unit : (start + 1) * unit
        ].set(new_rows)
        tail = arr[(start + count) * unit : n * unit]
        npad = S * unit - head.shape[0] - tail.shape[0]
        pad = jnp.broadcast_to(
            fill, (npad,) + arr.shape[1:]
        ).astype(arr.dtype)
        return jnp.concatenate([head, tail, pad])

    rep = {"n_seg": jnp.asarray(n_new, jnp.int32)}
    NB = st.blocks_pad
    if st.bits:
        rep["fused"] = splice(st.fused, NB, rows["fused"], 0)
    else:
        rep["blocks"] = splice(st.blocks, NB, rows["blocks"], PAD)
        rep["occ"] = splice(st.occ, 1, rows["occ"][None], 0)
    rep["c_array"] = splice(st.c_array, 1, rows["c_array"][None], 0)
    # pad segments clamp blk to 0 and start with ep == 0 (stack invariant)
    rep["n_blocks"] = splice(st.n_blocks, 1, rows["n_blocks"][None], 1)
    rep["lengths"] = splice(st.lengths, 1, rows["lengths"][None], 0)
    if st.sa_sample_rate:
        MW = st.sa_marks.shape[0] // st.seg_pad
        MV = st.sa_vals.shape[0] // st.seg_pad
        rep["sa_marks"] = splice(st.sa_marks, MW, rows["sa_marks"], 0)
        rep["sa_mark_ranks"] = splice(
            st.sa_mark_ranks, MW, rows["sa_mark_ranks"], 0
        )
        rep["sa_vals"] = splice(st.sa_vals, MV, rows["sa_vals"], 0)
    return dataclasses.replace(st, **rep)


def _stacked_occ_batch(st: StackedFMIndex, seg, c, p):
    """Occ(c_i, p_i) inside segment seg_i — flat int32[Q] lanes, one
    kernels/ops dispatch for the whole catalog (the fan-out hot path)."""
    r = st.sample_rate
    blk = jnp.minimum(p // r, st.n_blocks[seg] - 1)
    cut = p - blk * r
    row = seg * st.blocks_pad + blk
    if st.bits:
        return ops.rank_packed(st.fused, row, c, cut,
                               bits=st.bits, sigma=st.sigma)
    base = st.occ[seg, blk, c]
    return base + ops.rank_unpacked(st.blocks, row, c, cut)


def _stacked_backward_search(st: StackedFMIndex, patterns: jax.Array):
    """(sp, ep) int32[S, B]: every pattern against every segment, two rank
    dispatches per scan step (``_interval_step`` — the exact transition of
    ``backward_search_batch`` — over lanes flattened to segments x batch).
    """
    S, B = st.seg_pad, patterns.shape[0]
    seg = jnp.repeat(jnp.arange(S, dtype=jnp.int32), B)

    def rank(c, p):
        cf, pf = c.reshape(-1), p.reshape(-1)
        return (st.c_array[seg, cf]
                + _stacked_occ_batch(st, seg, cf, pf)).reshape(S, B)

    def step(state, c):
        cB = jnp.broadcast_to(c[None, :], (S, B))
        return _interval_step(cB, *state, st.sigma, rank), None

    init = (jnp.zeros((S, B), jnp.int32),
            jnp.broadcast_to(st.lengths[:, None], (S, B)))
    (sp, ep), _ = lax.scan(step, init, patterns.T[::-1])
    return sp, ep


@jax.jit
def count_stacked(st: StackedFMIndex, patterns: jax.Array) -> jax.Array:
    """Per-segment exact-match counts, int32[S, B] for int32[B, m]
    PAD-padded patterns; row s is bit-identical to ``count`` on segment s
    alone (pad-segment rows are all zero)."""
    sp, ep = _stacked_backward_search(st, patterns)
    return jnp.maximum(ep - sp, 0)


@functools.partial(jax.jit, static_argnames=("k",))
def locate_stacked(
    st: StackedFMIndex, patterns: jax.Array, k: int
) -> tuple[jax.Array, jax.Array]:
    """Per-segment first-k locate: (positions int32[S, B, k] segment-local,
    sorted, filled with the segment length; counts int32[S, B] clipped to
    k).  Row s is bit-identical to ``locate`` on segment s alone; the
    caller offsets to global coordinates and merges."""
    if st.sa_sample_rate == 0:
        raise ValueError("catalog stacked without SA samples — no locate")
    sp, ep = _stacked_backward_search(st, patterns)
    S, B = sp.shape
    seg = jnp.repeat(jnp.arange(S, dtype=jnp.int32), B * k)
    rows = sp[:, :, None] + jnp.arange(k, dtype=jnp.int32)[None, None, :]
    valid = (rows < ep[:, :, None]).reshape(-1)
    rows = jnp.where(valid, rows.reshape(-1), 0)

    # per-segment SA-sample strides in the flat (segment-major) arrays:
    # pseudo-row seg*MW*32 + row lands on segment seg's mark words, and
    # idx_offset shifts into its slice of the value stream
    MW = st.sa_marks.shape[0] // st.seg_pad
    MV = st.sa_vals.shape[0] // st.seg_pad

    def lookup(rows):
        return sample_lookup(st.sa_marks, st.sa_mark_ranks, st.sa_vals,
                             seg * (MW * 32) + rows, idx_offset=seg * MV)

    def lf_next(rows):
        r = st.sample_rate
        blk = seg * st.blocks_pad + rows // r
        if st.bits:
            c = packed_symbol(st.fused, blk, rows % r,
                              sigma=st.sigma, bits=st.bits)
        else:
            c = st.blocks[blk, rows % r]
        return st.c_array[seg, c] + _stacked_occ_batch(st, seg, c, rows)

    pos = _locate_walk(st.sa_sample_rate, rows, valid, lookup, lf_next)
    fill = jnp.repeat(st.lengths, B * k)
    out = jnp.where(valid, pos, fill).reshape(S, B, k)
    return (jnp.sort(out, axis=2),
            jnp.minimum(jnp.maximum(ep - sp, 0), k))


def locate_naive(index: FMIndex, sa: jax.Array, pattern: jax.Array) -> jax.Array:
    """Occurrence positions via a full SA (test oracle for ``locate``)."""
    sp, ep = backward_search(index, pattern)
    return jnp.sort(jnp.where(
        (jnp.arange(index.n) >= sp) & (jnp.arange(index.n) < ep), sa, index.n
    ))


def count_naive(text, pattern) -> int:
    """Overlapping substring-count numpy oracle."""
    text, pattern = np.asarray(text), np.asarray(pattern)
    m = len(pattern)
    if m == 0 or m > len(text):
        return 0
    windows = np.lib.stride_tricks.sliding_window_view(text, m)
    return int((windows == pattern).all(axis=1).sum())
