"""FM-index over a BWT: C array, sampled Occ checkpoints, backward search.

This is the "full-text index that enables fast querying" the paper builds
toward (§1): exact pattern matching in O(m) rank queries per pattern,
independent of the indexed-text length.

Layout (all dense arrays, shard- and jit-friendly):

* ``bwt``          int32[n]      last column
* ``C``            int32[sigma]  # chars strictly smaller (exclusive cumsum)
* ``occ_samples``  int32[n/r + 1, sigma]  checkpointed exclusive Occ counts
* rank(c, p) = occ_samples[p // r, c] + count of c in bwt[(p//r)*r : p]

``sample_rate`` trades memory (n*sigma/r ints) for per-query scan length r —
the classic FM-index trade-off the paper cites ([4] Ferragina-Manzini).
The in-block count is the hot spot; ``kernels/rank_select`` provides the
Pallas TPU version, this module is the jnp reference.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

PAD = -1  # query padding token


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FMIndex:
    bwt: jax.Array          # int32[n_blocks * r], PAD beyond position n
    row: jax.Array          # scalar int32: row of the original string
    c_array: jax.Array      # int32[sigma]
    occ_samples: jax.Array  # int32[n_blocks + 1, sigma]
    sample_rate: int        # static (pytree aux data)
    sigma: int              # static (pytree aux data)
    length: int             # static: true text length n

    @property
    def n(self) -> int:
        return self.length

    def tree_flatten(self):
        return ((self.bwt, self.row, self.c_array, self.occ_samples),
                (self.sample_rate, self.sigma, self.length))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def build_fm_index(
    bwt_arr: jax.Array, row: jax.Array, sigma: int, sample_rate: int = 64
) -> FMIndex:
    n = bwt_arr.shape[0]
    counts = jnp.bincount(bwt_arr, length=sigma)
    c_array = (jnp.cumsum(counts) - counts).astype(jnp.int32)

    n_blocks = -(-n // sample_rate)  # ceil
    pad = n_blocks * sample_rate - n
    padded = jnp.pad(bwt_arr, (0, pad), constant_values=PAD)
    onehot = (padded[:, None] == jnp.arange(sigma)[None, :]).astype(jnp.int32)
    block_counts = onehot.reshape(n_blocks, sample_rate, sigma).sum(axis=1)
    occ_samples = jnp.concatenate(
        [jnp.zeros((1, sigma), jnp.int32), jnp.cumsum(block_counts, axis=0)]
    )  # exclusive checkpoints: occ_samples[k] counts bwt[: k*r]
    # the padded copy keeps every in-block dynamic_slice in bounds
    return FMIndex(padded, jnp.asarray(row, jnp.int32), c_array, occ_samples,
                   sample_rate, sigma, n)


def occ(index: FMIndex, c: jax.Array, p: jax.Array) -> jax.Array:
    """# occurrences of character ``c`` in ``bwt[:p]`` (exclusive rank)."""
    r = index.sample_rate
    block = p // r
    base = index.occ_samples[block, c]
    start = block * r
    # count c in bwt[start : p] — fixed-width window + position mask
    window = lax.dynamic_slice(index.bwt, (start,), (r,))
    inblock = jnp.sum((window == c) & (start + jnp.arange(r) < p))
    return base + inblock.astype(jnp.int32)


def backward_search(index: FMIndex, pattern: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(sp, ep) suffix-array interval of ``pattern`` (PAD-padded on the right).

    Count of exact occurrences is ``ep - sp``.
    """
    n = index.n

    def step(state, c):
        sp, ep = state
        in_alphabet = (c >= 1) & (c < index.sigma)
        valid = in_alphabet & (ep > sp)
        c_safe = jnp.where(in_alphabet, c, 0)
        nsp = index.c_array[c_safe] + occ(index, c_safe, sp)
        nep = index.c_array[c_safe] + occ(index, c_safe, ep)
        # PAD steps are no-ops; an already-empty interval stays empty;
        # an out-of-alphabet symbol (unknown to the index) empties it
        sp = jnp.where(valid, nsp, sp)
        ep = jnp.where(valid, nep, jnp.where((c != PAD) & ~in_alphabet, sp, ep))
        return (sp, ep), None

    # process right-to-left; PADs sit on the right so they come first and
    # are skipped by ``valid``
    (sp, ep), _ = lax.scan(step, (jnp.int32(0), jnp.int32(n)), pattern[::-1])
    return sp, ep


@jax.jit
def count(index: FMIndex, patterns: jax.Array) -> jax.Array:
    """Batched exact-match counts: patterns int32[B, m] PAD-padded."""
    sp, ep = jax.vmap(lambda p: backward_search(index, p))(patterns)
    return jnp.maximum(ep - sp, 0)


def locate_naive(index: FMIndex, sa: jax.Array, pattern: jax.Array) -> jax.Array:
    """Occurrence positions via a full SA (test oracle — production locate
    would use an SA sample, out of the paper's scope)."""
    sp, ep = backward_search(index, pattern)
    return jnp.sort(jnp.where(
        (jnp.arange(index.n) >= sp) & (jnp.arange(index.n) < ep), sa, index.n
    ))


def count_naive(text, pattern) -> int:
    """Overlapping substring-count numpy oracle."""
    import numpy as np

    text, pattern = np.asarray(text), np.asarray(pattern)
    m = len(pattern)
    if m == 0 or m > len(text):
        return 0
    windows = np.lib.stride_tricks.sliding_window_view(text, m)
    return int((windows == pattern).all(axis=1).sum())
