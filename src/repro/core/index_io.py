"""Index lifecycle IO: versioned checkpoint/restore of built FM indexes.

The paper's index is a *persistent distributed artifact* — it must outlive
the process that built it and come back up on whatever hardware is
available.  This module serialises :class:`~repro.core.pipeline.SequenceIndex`
(wrapping either a single-device ``FMIndex`` or a sharded ``DistFMIndex``)
through the same atomic/keep-k :class:`~repro.training.checkpoint.Checkpointer`
machinery the training loop uses, with a versioned manifest so formats can
evolve.

On-disk layout (one ``Checkpointer`` step directory per saved index):

    ckpt_dir/step_00000000/
      arrays.npz      bwt (GLOBAL, host-gathered), row, SA-sample bitvector
                      + packed/raw values — plus, for single-device indexes,
                      the derived layout (c_array, occ_samples, fused rows)
      meta.json       manifest: format/version, kind, static aux (sigma,
                      sample_rate, bits, sa_sample_rate, sa_val_bits, ...)

Re-mesh rule: only *mesh-independent* state is authoritative on disk.  The
global BWT and the replicated SA sample restore bit-identically anywhere;
the per-shard Occ checkpoints and fused packed rows of a ``DistFMIndex``
depend on the number of shards, so restore recomputes them (one cheap
counting pass inside ``build_dist_fm_index``) for whatever mesh is passed —
a checkpoint written from 8 devices serves from 4, 13, or 1.  Query results
are exact integer math over the same BWT, hence bit-identical across mesh
shapes (asserted by ``tests/dist_driver.py index_io``).
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax.numpy as jnp
from jax.sharding import Mesh

from ..training.checkpoint import Checkpointer
from .dist_fm import DistFMIndex, build_dist_fm_index
from .fm_index import FMIndex, build_fm_index
from .pipeline import SequenceIndex

FORMAT = "fm_index_ckpt"
VERSION = 1

# arrays every kind stores / arrays only the single-device layout stores
_COMMON = ("bwt", "row")
_SA_ARRAYS = ("sa_marks", "sa_mark_ranks", "sa_vals")
_FM_LAYOUT = ("c_array", "occ_samples", "fused")


class IndexIOError(Exception):
    """Base for typed index checkpoint errors.  Every subclass also
    derives from the stdlib exception a pre-typed caller would have seen
    (``FileNotFoundError`` / ``ValueError``), so existing handlers keep
    working while new callers can catch the whole family at once."""


class MissingCheckpointError(IndexIOError, FileNotFoundError):
    """No checkpoint where one was expected (empty dir, missing manifest
    or arrays file).  Actionable: point at a directory ``save_index``
    wrote, or rebuild and save the index."""


class CorruptCheckpointError(IndexIOError, ValueError):
    """The checkpoint exists but cannot be trusted: unreadable/truncated
    arrays, a manifest that is not an index manifest, or arrays
    inconsistent with the manifest.  Actionable: restore an earlier
    ``step`` (``save_index`` keeps ``keep`` of them) or rebuild."""


class UnsupportedVersionError(IndexIOError, ValueError):
    """Checkpoint written by a newer format revision.  Actionable:
    upgrade this build; the artifact itself is healthy."""


def _manifest(fm, text_length: int) -> dict:
    kind = "dist_fm" if isinstance(fm, DistFMIndex) else "fm"
    return {
        "format": FORMAT,
        "version": VERSION,
        "kind": kind,
        "sample_rate": fm.sample_rate,
        "sigma": fm.sigma,
        "length": fm.length,
        "bits": fm.bits,
        "sa_sample_rate": fm.sa_sample_rate,
        "sa_val_bits": fm.sa_val_bits,
        "text_length": text_length,
        "built_parts": getattr(fm, "parts", 1),  # informational only
    }


def save_index(directory: str, index, *, step: int = 0, keep: int = 3) -> int:
    """Checkpoint a built index; returns the step written.

    ``index`` is a ``SequenceIndex`` or a bare ``FMIndex``/``DistFMIndex``.
    Arrays are host-gathered before writing (the ``Checkpointer`` elastic
    rule), so a sharded index saves as one global BWT.  Atomic: a crash
    mid-save never corrupts the previous step; ``keep`` old steps are
    retained.
    """
    fm = index.fm if isinstance(index, SequenceIndex) else index
    text_length = (
        index.text_length if isinstance(index, SequenceIndex) else fm.length
    )
    tree = {"bwt": fm.bwt, "row": fm.row}
    if fm.sa_sample_rate:
        for name in _SA_ARRAYS:
            tree[name] = getattr(fm, name)
    if isinstance(fm, FMIndex):
        # the derived layout is cheap to store and makes single-device
        # restore a pure reconstruction (no recompute at all)
        tree["c_array"] = fm.c_array
        tree["occ_samples"] = fm.occ_samples
        if fm.fused is not None:
            tree["fused"] = fm.fused
    manifest = _manifest(fm, text_length)
    manifest["arrays"] = sorted(tree)
    Checkpointer(directory, keep=keep).save(step, tree, extra=manifest)
    return step


def _check_manifest(meta: dict) -> None:
    if meta.get("format") != FORMAT:
        raise CorruptCheckpointError(
            f"not an index checkpoint (format={meta.get('format')!r})"
        )
    if meta.get("version", 0) > VERSION:
        raise UnsupportedVersionError(
            f"index checkpoint version {meta['version']} is newer than this "
            f"build supports ({VERSION}); upgrade the reader — the artifact "
            "itself is fine"
        )


def _load_raw(directory: str, step: int | None):
    """``Checkpointer.restore_raw`` with untyped filesystem/zip failures
    mapped to the typed error family, plus array-vs-manifest validation
    (missing leaves, truncated ``bwt``)."""
    import zipfile

    try:
        flat, meta = Checkpointer(directory).restore_raw(step)
    except FileNotFoundError as e:
        raise MissingCheckpointError(
            f"no readable index checkpoint under {directory!r}: {e}. "
            "Expected a step directory with meta.json + arrays.npz "
            "(written by save_index)."
        ) from e
    except (zipfile.BadZipFile, json.JSONDecodeError, OSError,
            KeyError) as e:
        raise CorruptCheckpointError(
            f"index checkpoint under {directory!r} is unreadable ({e}); "
            "restore an earlier step or rebuild the index"
        ) from e
    _check_manifest(meta)
    declared = meta.get("arrays")
    if declared:
        missing = sorted(set(declared) - set(flat))
        if missing:
            raise CorruptCheckpointError(
                f"index checkpoint under {directory!r} is missing arrays "
                f"{missing} declared by its manifest; restore an earlier "
                "step or rebuild the index"
            )
    if "bwt" in flat and flat["bwt"].shape[0] < meta.get("length", 0):
        raise CorruptCheckpointError(
            f"index checkpoint under {directory!r} has a truncated bwt "
            f"({flat['bwt'].shape[0]} < manifest length {meta['length']}); "
            "restore an earlier step or rebuild the index"
        )
    return flat, meta


def restore_index(
    directory: str, mesh: Mesh | None = None, *, step: int | None = None
) -> SequenceIndex:
    """Restore a checkpointed index, ready to serve.

    ``mesh=None`` restores to a single-device ``FMIndex``; with a mesh the
    BWT is re-sharded over its ``parts`` axis and the per-shard layout
    recomputed — independent of the mesh shape the checkpoint was written
    from.  Counting/locating on the restored index is bit-identical to the
    index that was saved.  Raises if the padded length does not divide the
    new ``parts * sample_rate`` (pick a compatible mesh, or restore
    single-device).
    """
    flat, meta = _load_raw(directory, step)
    sample_rate = meta["sample_rate"]
    sigma = meta["sigma"]
    srate = meta["sa_sample_rate"]
    bwt = jnp.asarray(flat["bwt"][: meta["length"]])
    row = jnp.asarray(flat["row"])
    sa_samples = None
    if srate:
        sa_samples = tuple(jnp.asarray(flat[k]) for k in _SA_ARRAYS) + (
            meta["sa_val_bits"],
        )

    if mesh is None:
        if meta["kind"] == "fm" and "occ_samples" in flat:
            # pure reconstruction from the stored layout
            fm = FMIndex(
                jnp.asarray(flat["bwt"]), row, jnp.asarray(flat["c_array"]),
                jnp.asarray(flat["occ_samples"]),
                jnp.asarray(flat["fused"]) if "fused" in flat else None,
                *(sa_samples[:3] if sa_samples else (None, None, None)),
                sample_rate, sigma, meta["length"], meta["bits"],
                srate, meta["sa_val_bits"],
            )
        else:  # dist checkpoint onto one device: rebuild the local layout
            fm = build_fm_index(
                bwt, row, sigma, sample_rate, pack=bool(meta["bits"]),
                sa_samples=sa_samples, sa_sample_rate=srate,
            )
    else:
        fm = build_dist_fm_index(
            bwt, row, mesh, sigma=sigma, sample_rate=sample_rate,
            pack=bool(meta["bits"]),
            sa_samples=sa_samples, sa_sample_rate=srate,
        )
    return SequenceIndex(
        fm, None, fm.bwt, row, sigma, meta["length"], meta["text_length"],
        mesh=mesh,
    )


def latest_index_step(directory: str) -> int | None:
    """Newest saved step under ``directory`` (None when empty) — the serve
    launcher's restore-or-build decision."""
    return Checkpointer(directory).latest_step()


@dataclasses.dataclass(frozen=True)
class IndexInfo:
    """Human-readable summary of a checkpointed index (``describe_index``)."""

    kind: str
    step: int
    sigma: int
    length: int
    text_length: int
    sample_rate: int
    bits: int
    sa_sample_rate: int
    sa_val_bits: int


def describe_index(directory: str, step: int | None = None) -> IndexInfo:
    """Read just the manifest of a saved index (no array IO)."""
    if step is None:
        step = Checkpointer(directory).latest_step()
        if step is None:
            raise MissingCheckpointError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "meta.json")
    try:
        with open(path) as f:
            meta = json.load(f)
    except FileNotFoundError as e:
        raise MissingCheckpointError(
            f"checkpoint step {step} under {directory!r} has no manifest "
            f"({path} is missing) — the save was torn; restore an earlier "
            "step or re-save"
        ) from e
    except (OSError, json.JSONDecodeError) as e:
        raise CorruptCheckpointError(
            f"manifest {path!r} is unreadable ({e}); restore an earlier "
            "step or rebuild"
        ) from e
    _check_manifest(meta)
    return IndexInfo(
        meta["kind"], step, meta["sigma"], meta["length"],
        meta["text_length"], meta["sample_rate"], meta["bits"],
        meta["sa_sample_rate"], meta["sa_val_bits"],
    )
