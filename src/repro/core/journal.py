"""Crash-safe generation commits for on-disk catalogs.

A ``SegmentedIndex`` catalog is a directory of immutable segment artifacts
plus one mutable description of which segments are live.  The pre-journal
``save`` deleted orphans and rewrote ``catalog.json`` with no ordering
guarantees — a crash mid-save could leave a catalog that references
deleted segments, or a half-written description.  This module makes every
catalog mutation a **two-phase generation commit**:

1. *Stage*: write every new artifact file (failpoints ``io.write``),
   fsync them (``io.fsync``), then write a **generation manifest**
   ``gen_<g>.json`` — the full catalog payload plus a CRC32 + size per
   live artifact file — and fsync it too.  Nothing written so far is
   referenced by the committed state; a crash anywhere in this phase
   leaves the previous generation fully intact.
2. *Commit*: atomically replace the ``CURRENT`` pointer file with the new
   generation's name (``io.rename`` failpoint, then ``os.replace`` —
   POSIX-atomic).  This single rename is the commit point.
3. *Garbage-collect* (only after commit): delete artifacts the committed
   generation no longer references, older generation manifests, and stray
   ``*.tmp`` staging files.

``committed()`` reads the pointer and validates the manifest it names,
rolling back through older on-disk generations if the pointed-to one is
torn (can only happen with a corrupted filesystem — the commit ordering
never produces it).  ``recover()`` removes everything a torn generation
staged, restoring the invariant that the directory holds exactly the
committed generation's files.  Readers verify artifact CRCs
(``restore.checksum`` failpoint) and quarantine — rather than serve —
anything that does not match.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib

from ..testing.faultinject import checksum_fault, fault_point

CURRENT = "CURRENT"
GEN_PREFIX = "gen_"
GEN_FMT = GEN_PREFIX + "{:08d}.json"
QUARANTINE = "quarantine"


def crc32_path(path: str, chunk: int = 1 << 20) -> int:
    """Streaming CRC32 of a file (zlib polynomial, unsigned)."""
    crc = 0
    with open(path, "rb") as f:
        while block := f.read(chunk):
            crc = zlib.crc32(block, crc)
    return crc & 0xFFFFFFFF


def fsync_path(path: str) -> None:
    """fsync one file (failpoint ``io.fsync`` first)."""
    fault_point("io.fsync")
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: str) -> None:
    """Best-effort directory fsync (durable rename on POSIX)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def write_file_durable(path: str, data: bytes) -> None:
    """Write ``path`` via a same-directory tmp + fsync + atomic rename.

    Failpoints: ``io.write`` before the write, ``io.fsync`` before the
    fsync, ``io.rename`` before the publishing rename — a crash at any of
    them leaves at most a ``*.tmp`` file, never a torn ``path``."""
    tmp = path + ".tmp"
    fault_point("io.write")
    with open(tmp, "wb") as f:
        f.write(data)
        fault_point("io.fsync")
        f.flush()
        os.fsync(f.fileno())
    fault_point("io.rename")
    os.replace(tmp, path)


def verify_file(base_dir: str, relpath: str, want: dict) -> str | None:
    """Why ``relpath`` fails verification against its manifest entry
    ``{"crc32", "size"}``, or None when it checks out.  The
    ``restore.checksum`` failpoint simulates a torn read: a hit reports a
    mismatch instead of raising."""
    path = os.path.join(base_dir, relpath)
    if not os.path.isfile(path):
        return "missing"
    size = os.path.getsize(path)
    if size != want["size"]:
        return f"size {size} != {want['size']}"
    if checksum_fault():
        return "checksum mismatch (injected)"
    crc = crc32_path(path)
    if crc != want["crc32"]:
        return f"crc32 {crc:#010x} != {want['crc32']:#010x}"
    return None


def manifest_entry(base_dir: str, relpath: str) -> dict:
    path = os.path.join(base_dir, relpath)
    return {"crc32": crc32_path(path), "size": os.path.getsize(path)}


class GenerationJournal:
    """The two-phase commit protocol over one catalog directory."""

    def __init__(self, directory: str):
        self.dir = directory

    # -- read side -----------------------------------------------------------

    def _gen_path(self, gen: int) -> str:
        return os.path.join(self.dir, GEN_FMT.format(gen))

    def on_disk_generations(self) -> list[int]:
        """Generation numbers with a manifest file present, ascending."""
        if not os.path.isdir(self.dir):
            return []
        out = []
        for name in os.listdir(self.dir):
            if name.startswith(GEN_PREFIX) and name.endswith(".json"):
                try:
                    out.append(int(name[len(GEN_PREFIX):-len(".json")]))
                except ValueError:
                    continue
        return sorted(out)

    def _read_manifest(self, gen: int) -> dict | None:
        """The manifest of ``gen`` if it parses and self-identifies."""
        try:
            with open(self._gen_path(gen)) as f:
                man = json.load(f)
        except (OSError, json.JSONDecodeError):
            return None
        if man.get("generation") != gen or "files" not in man \
                or "catalog" not in man:
            return None
        return man

    def committed(self) -> dict | None:
        """The committed generation manifest (None: no journal here).

        Follows the ``CURRENT`` pointer; if the pointed-to manifest is
        unreadable (torn filesystem), rolls back to the newest older
        generation whose manifest parses."""
        cur = os.path.join(self.dir, CURRENT)
        gens = self.on_disk_generations()
        pointed = None
        try:
            with open(cur) as f:
                pointed = int(f.read().strip())
        except (OSError, ValueError):
            pointed = None
        candidates = []
        if pointed is not None:
            candidates.append(pointed)
        candidates += [g for g in reversed(gens)
                       if pointed is None or g < pointed]
        for gen in candidates:
            man = self._read_manifest(gen)
            if man is not None:
                return man
        return None

    # -- write side ----------------------------------------------------------

    def commit(self, catalog: dict, files: dict[str, dict]) -> dict:
        """Phase 2: publish a new generation.

        ``files`` maps artifact relpaths (already written AND fsynced by
        the caller) to ``{"crc32", "size"}`` entries.  Writes the
        generation manifest durably, then atomically flips ``CURRENT``.
        Returns the committed manifest."""
        prev = self.committed()
        gen = (prev["generation"] + 1) if prev else 0
        man = {"generation": gen, "catalog": catalog, "files": files}
        payload = json.dumps(man, indent=2).encode()
        write_file_durable(self._gen_path(gen), payload)
        # the commit point: one atomic pointer replace
        write_file_durable(os.path.join(self.dir, CURRENT),
                           f"{gen}\n".encode())
        fsync_dir(self.dir)
        return man

    def collect_garbage(self, keep_files) -> list[str]:
        """Post-commit / post-recovery sweep: delete stray ``*.tmp`` files,
        non-committed generation manifests, and any ``seg_*`` artifact
        path not in ``keep_files`` (an iterable of live relpaths).
        Returns the relpaths removed.  Never touches ``quarantine/``."""
        man = self.committed()
        keep_gen = man["generation"] if man else None
        keep = set(keep_files)
        removed = []
        for root, dirs, names in os.walk(self.dir, topdown=True):
            dirs[:] = [d for d in dirs if d != QUARANTINE]
            for name in names:
                rel = os.path.relpath(os.path.join(root, name), self.dir)
                if name.endswith(".tmp"):
                    removed.append(rel)
                elif name.startswith(GEN_PREFIX) and name.endswith(".json") \
                        and root == self.dir:
                    try:
                        g = int(name[len(GEN_PREFIX):-len(".json")])
                    except ValueError:
                        continue
                    if g != keep_gen:
                        removed.append(rel)
                elif rel.startswith("seg_") and rel not in keep:
                    removed.append(rel)
        for rel in removed:
            try:
                os.remove(os.path.join(self.dir, rel))
            except OSError:
                pass
        # prune now-empty segment directories left by file-level GC
        for root, dirs, names in os.walk(self.dir, topdown=False):
            base = os.path.basename(root)
            if base.startswith("seg_") or base.startswith("step_"):
                try:
                    os.rmdir(root)
                except OSError:
                    pass
        return removed

    def quarantine(self, relpath: str) -> str | None:
        """Move one artifact directory (or file) under ``quarantine/`` —
        corrupt data is withdrawn from serving but preserved for
        forensics.  Returns the new path (None if it vanished)."""
        src = os.path.join(self.dir, relpath)
        if not os.path.exists(src):
            return None
        qdir = os.path.join(self.dir, QUARANTINE)
        os.makedirs(qdir, exist_ok=True)
        dst = os.path.join(qdir, relpath.replace(os.sep, "__"))
        if os.path.exists(dst):
            if os.path.isdir(dst):
                shutil.rmtree(dst)
            else:
                os.remove(dst)
        os.replace(src, dst)
        return dst
