"""End-to-end indexing pipeline: tokens -> (SA, BWT, FM-index).

Public API used by examples, benchmarks, and the data-pipeline dedup stage.
Dispatches between the single-device reference path and the distributed
shard_map path (any mesh with a ``parts`` axis).

Padding note: SPMD needs n divisible by parts*sample_rate.  We append the
unique smallest sentinel first (required by the BWT), then pad with a
dedicated token HIGHER than every real token.  Pad suffixes consist only of
pad tokens, so they can never match a query over the real alphabet, and real
char ranks are unaffected — counting semantics are exact (asserted by tests).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import alphabet as al
from .bwt import bwt_from_sa
from .dist_fm import DistFMIndex, build_dist_fm_index, dist_count, dist_locate
from .dist_suffix_array import (
    DistSAConfig,
    _bwt_jit,
    build_isa_sharded,
    isa_overflowed,
)
from .fm_index import (
    FMIndex,
    build_fm_index,
    count as fm_count,
    locate as fm_locate,
)
from .suffix_array import BuildStats, suffix_array, suffix_array_fast


@dataclasses.dataclass
class SequenceIndex:
    """A built full-text index plus query methods."""

    fm: FMIndex | DistFMIndex
    sa: jax.Array | None
    bwt: jax.Array
    row: jax.Array
    sigma: int
    length: int          # padded length
    text_length: int     # true length incl. sentinel
    mesh: Mesh | None = None
    build_stats: BuildStats | None = None  # fast-build trajectory (1-device)

    def count(self, patterns) -> jax.Array:
        """Exact-match counts for int32[B, L] PAD-padded patterns."""
        patterns = jnp.asarray(patterns, jnp.int32)
        if self.mesh is None:
            return fm_count(self.fm, patterns)
        return dist_count(self.fm, patterns, self.mesh)

    def locate(self, patterns, k: int) -> tuple[jax.Array, jax.Array]:
        """First-k occurrence positions per pattern via the SA sample built
        during indexing.  Returns (positions int32[B, k] sorted, filled with
        the padded length for unused slots; counts int32[B] clipped to k)."""
        patterns = jnp.asarray(patterns, jnp.int32)
        if self.mesh is None:
            return fm_locate(self.fm, patterns, k)
        return dist_locate(self.fm, patterns, k, self.mesh)


def prepare_tokens(
    tokens: np.ndarray, multiple: int, sigma: int | None = None,
    reserve_pad: bool | None = None,
) -> tuple[np.ndarray, int]:
    """Sentinel-terminate and pad to a multiple; returns (padded, sigma).

    ``sigma`` forces a minimum alphabet size (tokens in [1, sigma)): indexes
    built over different texts then share one alphabet, so the pad token
    (placed at the shared sigma) sorts above every real token of *any* of
    them — required by the segmented index, where a query may carry tokens
    absent from this particular segment.

    ``reserve_pad`` keeps the pad slot in the alphabet even when no padding
    tokens are appended.  Default (None) reserves it exactly for
    declared-``sigma`` builds, so every such index lands on the *same*
    effective sigma (and therefore the same fused-row layout) regardless of
    its length — the invariant the stacked segment-parallel query path
    relies on (``fm_index.stack_fm_indexes``).  Note this costs one
    alphabet slot: a declared sigma=16 build lands on 17 and falls out of
    the 4-bit packed layout; pass ``reserve_pad=False`` to opt out when the
    index will never be stacked.
    """
    s = al.append_sentinel(np.asarray(tokens, dtype=np.int32))
    data_sigma = al.sigma_of(s)
    declared = sigma is not None
    if declared and sigma < data_sigma:
        raise ValueError(f"tokens exceed declared alphabet {sigma}")
    if reserve_pad is None:
        reserve_pad = declared
    sigma = max(data_sigma, sigma or 0)
    pad = (-len(s)) % multiple
    if pad:
        s = np.concatenate([s, np.full(pad, sigma, np.int32)])
    if pad or reserve_pad:
        sigma += 1
    return s, sigma


def build_index_prepared(
    s: np.ndarray,
    sigma: int,
    *,
    sample_rate: int = 64,
    sa_config: DistSAConfig = DistSAConfig(),
    sa_sample_rate: int = 32,
    pack: bool | None = None,
    fast: bool = True,
    compress_sa: bool | None = None,
    text_length: int | None = None,
) -> SequenceIndex:
    """Single-device build over an already-prepared text.

    ``s`` is a ``prepare_tokens``-style token array — or a concatenation of
    several such prepared documents, each carrying its own sentinel and pad
    run (the rebuild strategy of ``SegmentedIndex.compact`` and the oracle
    for ``core.bwt_merge``).  The prefix-doubling builders need no unique
    terminal sentinel: suffixes of a multi-document text are still pairwise
    distinct (different lengths resolve through the overflow rank), and
    queries over the real alphabet can never match a sentinel or pad, so
    counting semantics stay exact per document.
    """
    s_dev = jnp.asarray(s, jnp.int32)
    if fast:
        sa, stats = suffix_array_fast(
            s_dev, sigma, local_sort=sa_config.local_sort,
            qgram=sa_config.qgram, qgram_words=sa_config.qgram_words,
            discard=sa_config.discard,
        )
    else:
        sa, stats = suffix_array(s_dev, sigma), None
    bwt_arr, row = bwt_from_sa(s_dev, sa)
    sa_kw = dict(sa_sample_rate=sa_sample_rate) if sa_sample_rate else {}
    fm = build_fm_index(bwt_arr, row, sigma, sample_rate, pack=pack,
                        compress_sa=compress_sa,
                        sa=sa if sa_sample_rate else None, **sa_kw)
    n = int(s_dev.shape[0])
    return SequenceIndex(fm, sa, bwt_arr, row, sigma, n,
                         n if text_length is None else text_length,
                         build_stats=stats)


def build_index(
    tokens: np.ndarray,
    mesh: Mesh | None = None,
    *,
    sample_rate: int = 64,
    sa_config: DistSAConfig = DistSAConfig(),
    max_retries: int = 3,
    sa_sample_rate: int = 32,
    pack: bool | None = None,
    fast: bool = True,
    sigma: int | None = None,
    compress_sa: bool | None = None,
    reserve_pad: bool | None = None,
) -> SequenceIndex:
    """Build a (distributed) BWT/FM index over raw tokens (no sentinel).

    The suffix array produced as a build byproduct is sampled every
    ``sa_sample_rate``-th text position into the index, enabling
    ``SequenceIndex.locate`` (set 0 to skip).  ``pack`` as in
    ``build_fm_index`` (None = bit-pack when the alphabet fits);
    ``compress_sa`` as in ``build_sa_samples`` (None = bit-pack the SA
    sample whenever it shrinks it); ``sigma`` declares a minimum alphabet
    (see ``prepare_tokens`` — the segmented index passes its global one;
    ``reserve_pad`` as there, None = reserve the pad slot for declared
    alphabets so same-``sigma`` builds share one layout).

    ``sa_config`` also carries the build-engine knobs (qgram / discard /
    local_sort) for both the distributed and the single-device path; the
    single-device path uses the fused-key fast builder unless ``fast=False``
    (the seed ``lax.while_loop`` reference — same output bit-for-bit).

    With a mesh, retries samplesort capacity overflows with doubled factor —
    the explicit analogue of Spark skew recovery (DESIGN.md §4).
    """
    tokens = np.asarray(tokens, dtype=np.int32)
    text_length = len(tokens) + 1
    sa_kw = dict(sa_sample_rate=sa_sample_rate) if sa_sample_rate else {}

    if mesh is None:
        s, sigma = prepare_tokens(tokens, sample_rate, sigma, reserve_pad)
        return build_index_prepared(
            s, sigma, sample_rate=sample_rate, sa_config=sa_config,
            sa_sample_rate=sa_sample_rate, pack=pack, fast=fast,
            compress_sa=compress_sa, text_length=text_length,
        )

    parts = mesh.shape[sa_config.axis]
    s, sigma = prepare_tokens(tokens, parts * sample_rate, sigma,
                              reserve_pad)
    s_dev = jnp.asarray(s)
    cfg = sa_config
    for attempt in range(max_retries):
        isa = build_isa_sharded(s_dev, mesh, cfg, sigma=sigma)
        if not isa_overflowed(isa):
            break
        cfg = cfg._replace(capacity_factor=cfg.capacity_factor * 2)
    else:
        raise RuntimeError(
            f"samplesort capacity overflow after {max_retries} retries "
            f"(factor {cfg.capacity_factor})"
        )
    from jax.sharding import NamedSharding, PartitionSpec
    s_sharded = jax.device_put(
        s_dev, NamedSharding(mesh, PartitionSpec(cfg.axis))
    )
    sa, bwt_arr, row = _bwt_jit(s_sharded, isa, cfg, parts, mesh)
    fm = build_dist_fm_index(bwt_arr, row, mesh, sigma=sigma,
                             sample_rate=sample_rate, pack=pack,
                             compress_sa=compress_sa,
                             sa=sa if sa_sample_rate else None, **sa_kw)
    return SequenceIndex(fm, sa, bwt_arr, row, sigma, len(s), text_length,
                         mesh=mesh)
