"""Burrows-Wheeler transform from the suffix array, and its inverse.

The paper (§2.2) derives the BWT from the suffix array "in a MapReduce
fashion via join operation":  bwt[i] = S[(SA[i] - 1) mod n].  The row index
``I`` of the original string is the position where SA[i] == 0.

The inverse transform (LF-mapping walk) is implemented as a validation
oracle: BWT must be reversible (paper §2.1, "it is reversible").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .suffix_array import suffix_array


@jax.jit
def bwt_from_sa(s: jax.Array, sa: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(bwt, I): last column of the sorted rotation matrix + original row."""
    n = s.shape[0]
    prev = jnp.mod(sa - 1, n)
    bwt = s[prev]
    row = jnp.argmin(sa).astype(jnp.int32)  # position where sa == 0
    return bwt, row


@functools.partial(jax.jit, static_argnames=("sigma",))
def bwt(s: jax.Array, sigma: int) -> tuple[jax.Array, jax.Array]:
    """End-to-end single-device BWT (reference path)."""
    return bwt_from_sa(s, suffix_array(s, sigma))


@functools.partial(jax.jit, static_argnames=("sigma",))
def lf_mapping(bwt_arr: jax.Array, sigma: int) -> jax.Array:
    """LF[i] = C[bwt[i]] + occ(bwt[i], i)  (rank of bwt[i] among equal chars
    up to and including position i, minus one)."""
    counts = jnp.bincount(bwt_arr, length=sigma)
    c_array = jnp.cumsum(counts) - counts  # exclusive: chars < c
    onehot = jax.nn.one_hot(bwt_arr, sigma, dtype=jnp.int32)
    occ_incl = jnp.cumsum(onehot, axis=0)  # occ(c, 0..i) inclusive
    rank = jnp.take_along_axis(occ_incl, bwt_arr[:, None], axis=1)[:, 0] - 1
    return (c_array[bwt_arr] + rank).astype(jnp.int32)


def inverse_bwt(bwt_arr: jax.Array, row: jax.Array, sigma: int) -> jax.Array:
    """Reconstruct the original string by walking the LF mapping backwards
    from the row of the original rotation.  O(n * sigma) memory — a test
    oracle, not a production path."""
    n = bwt_arr.shape[0]
    lf = lf_mapping(bwt_arr, sigma)

    def step(i, _):
        return lf[i], bwt_arr[i]

    _, rev = jax.lax.scan(step, row, None, length=n)
    return rev[::-1]


def bwt_naive(s) -> tuple["np.ndarray", int]:  # noqa: F821 - numpy oracle
    """Rotation-sorting oracle (Figure 1 of the paper)."""
    import numpy as np

    s = np.asarray(s)
    n = len(s)
    rotations = sorted(range(n), key=lambda i: np.concatenate([s[i:], s[:i]]).tolist())
    last = np.array([s[(i - 1) % n] for i in rotations], dtype=s.dtype)
    return last, rotations.index(0)
