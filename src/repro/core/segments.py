"""Segmented incremental append: grow an index without a full rebuild.

The paper builds one monolithic index per dataset; Sirén's *BWT for
terabases* and the authors' follow-up *BWT on a Large Scale* instead build
large BWTs from per-chunk structures that are merged — the natural shape
for an index that must grow with its corpus.  This module is that idea in
LSM-tree form (as in Lucene-like search systems):

* ``append(tokens)`` builds a *new per-segment FM-index* over just the new
  text with the PR 2 fast builder — O(new segment), not O(corpus).
* ``count`` sums per-segment counts (each an independent, embarrassingly
  parallel backward search).
* ``locate`` maps per-segment positions to global coordinates and merges
  the candidate sets.
* ``compact`` folds runs of small adjacent segments into one segment,
  bounding per-query fan-out — the background-merge half of the LSM
  playbook.  The default strategy lets a **cost model** pick, per run,
  between the rebuild-free BWT merges of ``core.bwt_merge`` — the
  pairwise fold and the **k-way interleave walk** (all segments spliced
  in one walk, no intermediate indexes) — and the raw-token rebuild;
  ``strategy="rebuild"`` forces the re-sort and is the bit-identity
  oracle for both merge flavors.

Document semantics: every ``append`` creates one immutable *document*, and
matches never span documents — exactly as matches never span the documents
of a concatenated collection.  Compaction is **answer-invariant**: a
merged segment indexes the concatenation of its documents' *prepared*
texts (each sentinel-terminated and pad-filled), so old document
boundaries survive inside the merged text — no match ever appears or
disappears across a compact(), and counts (plus locate whenever a
pattern's occurrences fit within ``k``) are identical before and after,
a pure function of the append history (``tests/test_lifecycle_fuzz.py``
asserts this at every step of randomized lifecycles).  The one
non-guarantee: with MORE than ``k`` occurrences, *which* k are reported
follows per-segment SA order (the same first-k rule as the monolithic
index), and a merged segment's SA order differs from its parts' — under
either compaction strategy.  Relative to one monolithic index over the
raw concatenation, the segmented answer differs only by occurrences
crossing a document boundary.

All segments share one declared alphabet (``sigma``), so every segment's
pad token sorts above every real token of *any* segment and a query over
the global alphabet can never match padding (see
``pipeline.prepare_tokens``).
"""

from __future__ import annotations

import dataclasses
import io
import json
import math
import os
import warnings

import numpy as np

from .bwt_merge import (
    context_order_safe,
    kway_eligible,
    kway_walk_steps,
    merge_fm_indexes,
    merge_kway,
)
from .journal import (
    GenerationJournal,
    fsync_path,
    manifest_entry,
    verify_file,
    write_file_durable,
)
from .dist_suffix_array import DistSAConfig
from .fm_index import (
    StackedFMIndex,
    count_stacked,
    locate_stacked,
    stack_fm_indexes,
    stacked_append,
    stacked_replace_run,
)
from .pipeline import (
    SequenceIndex,
    build_index,
    build_index_prepared,
    prepare_tokens,
)

CATALOG_FORMAT = "segmented_index_catalog"
CATALOG_VERSION = 2  # v2: per-segment document tables (``docs``)

# compaction strategies: "merge" = cost-model auto-pick per run,
# "pairwise"/"kway" force one BWT-merge flavor (rebuild fallback for
# ineligible runs), "rebuild" = always re-sort from raw tokens (the
# bit-identity oracle)
COMPACT_STRATEGIES = ("merge", "pairwise", "kway", "rebuild")


@dataclasses.dataclass
class Segment:
    """One immutable index segment plus its placement in global coordinates.

    ``docs`` lists the documents inside the segment's indexed text, in
    *text* order: ``(raw_len, rel_start)`` per document, ``rel_start`` the
    document's raw-token offset relative to ``offset``.  A fresh append is
    one document; compaction concatenates document tables (documents may
    sit out of corpus order inside a merged text — ``rel_start`` carries
    the mapping).  ``tokens`` holds the raw tokens in the same text order.
    """

    seg_id: int
    offset: int            # global position of this segment's first token
    n_tokens: int          # raw appended tokens (no sentinel, no padding)
    index: SequenceIndex
    tokens: np.ndarray     # retained corpus slice — compact() rebuild input
    docs: tuple[tuple[int, int], ...] = None

    def __post_init__(self):
        if self.docs is None:
            self.docs = ((self.n_tokens, 0),)
        self.docs = tuple((int(a), int(b)) for a, b in self.docs)

    @property
    def multi_doc(self) -> bool:
        return len(self.docs) > 1

    def doc_tokens(self) -> list[np.ndarray]:
        """Raw token arrays per document, text order."""
        splits = np.cumsum([d[0] for d in self.docs])[:-1]
        return np.split(self.tokens, splits)


class SegmentedIndex:
    """An FM-index over a growing corpus, as a catalog of immutable segments.

    ``sigma`` declares the global alphabet: all appended tokens must lie in
    [1, sigma).  Build knobs (``sample_rate``, ``sa_sample_rate``,
    ``sa_config``, ``pack``, ``compress_sa``, ``reserve_pad``) apply to
    every segment build.  Query interface (``count`` / ``locate``) matches
    ``SequenceIndex``, so ``serving.engine.FMQueryServer`` serves a
    segmented index unchanged.
    """

    def __init__(self, sigma: int, *, sample_rate: int = 64,
                 sa_sample_rate: int = 32,
                 sa_config: DistSAConfig = DistSAConfig(),
                 pack: bool | None = None, compress_sa: bool | None = None,
                 segment_min_tokens: int | None = None,
                 parallel: bool | None = None,
                 reserve_pad: bool | None = None,
                 compact_strategy: str = "merge",
                 compact_trigger_ratio: float = 0.5,
                 compact_max_small: int = 8,
                 compact_cost_walk_ns: float = 800.0,
                 compact_cost_kway_walk_ns: float = 1600.0,
                 compact_cost_token_ns: float = 50.0,
                 compact_cost_sort_ns: float = 55.0,
                 compact_cost_merge_us: float = 10000.0,
                 compact_trigger_cost_ratio: float = 0.75):
        if sigma < 2:
            raise ValueError("sigma must cover at least one real token")
        if compact_strategy not in COMPACT_STRATEGIES:
            raise ValueError(f"unknown compact strategy {compact_strategy!r}")
        self.sigma = sigma
        self.sample_rate = sample_rate
        self.sa_sample_rate = sa_sample_rate
        self.sa_config = sa_config
        self.pack = pack
        self.compress_sa = compress_sa
        self.reserve_pad = reserve_pad
        self.segment_min_tokens = segment_min_tokens  # compact() default
        # segment-parallel query fan-out: None = auto (stacked dispatch
        # whenever >= 2 stackable segments), False = always sequential,
        # True = require the stacked path (raise if segments can't stack)
        self.parallel = parallel
        # background-compaction policy (maybe_compact): "merge" picks
        # pairwise / k-way / rebuild per run through the cost model below;
        # "pairwise"/"kway" force one merge flavor (rebuild stays the
        # fallback for ineligible runs); "rebuild" always re-sorts.
        # ``compact_trigger_ratio`` is the legacy fixed-ratio trigger knob,
        # accepted for catalog compatibility but no longer consulted: the
        # trigger is cost-based (see ``maybe_compact``).
        self.compact_strategy = compact_strategy
        self.compact_trigger_ratio = compact_trigger_ratio
        self.compact_max_small = compact_max_small
        # cost-model constants, rough per-unit wall costs calibrated on the
        # CPU backend (compact_bench --smoke): one sequential pairwise
        # rank-walk step (dispatch-latency bound), one k-way walk step (it
        # ranks every walker lane, so ~2x a pairwise step), one token of
        # vectorized splice/occ-resample work, one token*log2(n) of rebuild
        # sort work, and the fixed overhead of one merge operation (jit
        # entry, host splice) — the term that sinks the pairwise fold on
        # wide runs
        self.compact_cost_walk_ns = compact_cost_walk_ns
        self.compact_cost_kway_walk_ns = compact_cost_kway_walk_ns
        self.compact_cost_token_ns = compact_cost_token_ns
        self.compact_cost_sort_ns = compact_cost_sort_ns
        self.compact_cost_merge_us = compact_cost_merge_us
        self.compact_trigger_cost_ratio = compact_trigger_cost_ratio
        # compaction telemetry: merge-strategy runs that fell back to the
        # O(n log n) rebuild (surfaced through frontend metrics + catalog)
        self.compact_fallbacks = 0
        self.compact_last_fallback_reason: str | None = None
        self.compact_strategy_counts: dict[str, int] = {}
        self.compact_last_plan: dict | None = None
        self.segments: list[Segment] = []
        self._next_id = 0
        self._stacked_cache: object | None = None
        # segments load() withdrew from serving (checksum/restore failures):
        # catalog entries + reason.  A degraded catalog keeps serving the
        # healthy segments; quarantined global coordinates answer nothing.
        self.quarantined: list[dict] = []
        self._next_offset = 0  # first free global coordinate (survives holes)

    @classmethod
    def from_config(cls, sigma: int, cfg) -> "SegmentedIndex":
        """Build from a BWTIndexConfig's index/lifecycle knobs (the config's
        own ``sigma`` describes the full byte workload; segmented corpora
        pass their actual alphabet)."""
        return cls(
            sigma, sample_rate=cfg.sample_rate,
            sa_sample_rate=cfg.sa_sample_rate,
            sa_config=DistSAConfig(
                engine=cfg.engine, capacity_factor=cfg.capacity_factor,
                qgram=cfg.qgram, qgram_words=cfg.qgram_words,
                discard=cfg.discard, local_sort=cfg.local_sort,
            ),
            pack=cfg.pack, compress_sa=cfg.compress_sa,
            segment_min_tokens=cfg.segment_min_tokens,
            parallel=cfg.serve_parallel_segments,
            compact_strategy=cfg.compact_strategy,
            compact_trigger_ratio=cfg.compact_trigger_ratio,
            compact_max_small=cfg.compact_max_small,
            compact_cost_walk_ns=cfg.compact_cost_walk_ns,
            compact_cost_kway_walk_ns=cfg.compact_cost_kway_walk_ns,
            compact_cost_token_ns=cfg.compact_cost_token_ns,
            compact_cost_sort_ns=cfg.compact_cost_sort_ns,
            compact_cost_merge_us=cfg.compact_cost_merge_us,
            compact_trigger_cost_ratio=cfg.compact_trigger_cost_ratio,
        )

    # -- growth --------------------------------------------------------------

    @property
    def total_tokens(self) -> int:
        return sum(s.n_tokens for s in self.segments)

    @property
    def degraded(self) -> bool:
        """True when load() quarantined corrupt segments: the catalog
        serves, but a known slice of the corpus is missing."""
        return bool(self.quarantined)

    @property
    def coord_end(self) -> int:
        """One past the largest assigned global coordinate.  Equal to
        ``total_tokens`` except in a degraded catalog, where quarantined
        segments leave holes that new appends must not reuse."""
        return max(self.total_tokens, self._next_offset)

    def _build(self, tokens: np.ndarray) -> SequenceIndex:
        return build_index(
            tokens, sample_rate=self.sample_rate,
            sa_config=self.sa_config, sa_sample_rate=self.sa_sample_rate,
            pack=self.pack, sigma=self.sigma, compress_sa=self.compress_sa,
            reserve_pad=self.reserve_pad,
        )

    def append(self, tokens) -> Segment:
        """Index new text as a fresh one-document segment; O(len(tokens)).

        ``tokens`` int32[m] in [1, sigma).  The new segment occupies global
        positions [total_tokens, total_tokens + m).  When a stacked
        fan-out catalog is live and has spare bucket capacity, the new
        segment is written into it in place (no re-stack, no recompile —
        ``fm_index.stacked_append``).
        """
        tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
        if tokens.size == 0:
            raise ValueError("cannot append an empty segment")
        if tokens.min() < 1 or tokens.max() >= self.sigma:
            raise ValueError(
                f"tokens out of declared alphabet [1, {self.sigma})"
            )
        seg = Segment(self._next_id, self.coord_end, len(tokens),
                      self._build(tokens), tokens)
        self._next_offset = seg.offset + seg.n_tokens
        self._next_id += 1
        self.segments.append(seg)
        if isinstance(self._stacked_cache, StackedFMIndex):
            try:
                self._stacked_cache = stacked_append(
                    self._stacked_cache, seg.index.fm
                )
            except ValueError:
                self._stacked_cache = None  # full bucket: re-stack lazily
        else:
            self._stacked_cache = None
        return seg

    # -- compaction ----------------------------------------------------------

    def _prepared_text(self, seg: Segment) -> np.ndarray:
        """The segment's prepared text (sentinel-terminated, pad-filled
        documents, concatenated) — the exact token string its index
        covers, re-derived from the retained raw tokens."""
        return np.concatenate([
            prepare_tokens(d, self.sample_rate, self.sigma,
                           self.reserve_pad)[0]
            for d in seg.doc_tokens()
        ])

    def _est_costs(self, ordered: list[Segment]) -> dict:
        """Estimated wall cost (ns) per strategy for a canonically ordered
        run, from run sizes/counts alone (no token access).

        Both merge flavors walk every text but the first — the same
        ``n - n_first`` sequential rank steps, though the k-way step is
        costlier (it ranks every walker lane) — but the pairwise fold
        additionally splices and re-samples every intermediate
        accumulator (the fold runs right-to-left from the smallest
        operands, so the intermediate sizes are the suffix sums) and
        pays the fixed per-merge overhead k-1 times; the rebuild
        re-sorts everything.
        """
        lens = [s.n_tokens + len(s.docs) for s in ordered]  # ~prepared
        n = sum(lens)
        w = max(0, sum(lens[1:]) - 1)  # sequential walk steps
        fixed = self.compact_cost_merge_us * 1e3
        # right-assoc fold accumulator sizes (includes the final splice)
        suffixes = np.cumsum(lens[::-1])[1:]
        return {
            "pairwise": self.compact_cost_walk_ns * w
            + self.compact_cost_token_ns * float(suffixes.sum())
            + fixed * (len(lens) - 1),
            "kway": self.compact_cost_kway_walk_ns * w
            + self.compact_cost_token_ns * n + fixed,
            "rebuild": self.compact_cost_sort_ns * n
            * math.log2(max(n, 2)),
        }

    def _plan_run(self, run: list[Segment],
                  strategy: str | None = None) -> tuple[list[Segment], dict]:
        """(canonical text order, plan) for a compaction run.

        Candidate orders (stable, ties in corpus order): largest-first —
        the largest text is never walked by either merge flavor, so it
        saves the most walk steps — and, when they differ, singles-first
        (multi-document segments at the right end).  A single-document
        left operand is *provably* context-order safe (its tied pad/
        sentinel positions are always followed by more padding, which
        sorts above any continuation), while a multi-document left
        operand's safety depends on the actual tokens — so the second
        order rescues exactly the runs PR 5's right-operand restriction
        used to allow, without giving up the general case.  Queries
        cannot observe document order (``docs`` carries the
        global-coordinate mapping), so any order is answer-invariant;
        the strategies all build the plan's single chosen layout and
        stay bit-identical to each other.

        The plan picks the cheapest estimated strategy (``_est_costs``)
        among those the run is *eligible* for: the merge flavors require
        the layout conditions of ``bwt_merge.kway_eligible`` plus
        context-order safety of every operand against the text that
        follows it (``bwt_merge.context_order_safe`` — the exact,
        token-level check that lets merged multi-document segments sit
        anywhere in the run when their tokens permit).  ``strategy``
        forces one flavor ("merge" = cost-model auto); ineligible runs
        record the fallback reason.
        """
        if strategy is None:
            strategy = self.compact_strategy
        bysize = sorted(run, key=lambda s: -s.n_tokens)
        singles_first = ([s for s in bysize if not s.multi_doc]
                         + [s for s in bysize if s.multi_doc])
        candidates = [bysize]
        if singles_first != bysize:
            candidates.append(singles_first)
        # the canonical layout must NOT depend on the requested strategy:
        # a forced rebuild builds the same document order the merge
        # flavors would, keeping all strategies bit-identical oracles of
        # each other
        ordered, reason = bysize, None
        for cand in candidates:
            reason = kway_eligible([s.index.fm for s in cand])
            # only multi-document left operands need the token-level scan:
            # a single-document prepared text ends in its pad/sentinel run,
            # whose tied positions are always followed by more padding and
            # so sort above any continuation — provably safe, no scan
            if reason is None and any(s.multi_doc for s in cand[:-1]):
                texts = [self._prepared_text(s) for s in cand]
                for i in range(len(texts) - 1):
                    if not cand[i].multi_doc:
                        continue
                    if not context_order_safe(
                        texts[i], np.concatenate(texts[i + 1 :])
                    ):
                        reason = (
                            f"operand {i} is not context-order safe "
                            f"against the texts that follow it "
                            f"(tied document tails)"
                        )
                        break
            if reason is None:
                ordered = cand
                break
        if strategy == "rebuild":
            reason = "rebuild requested"
        est = self._est_costs(ordered)
        if reason is not None:
            chosen = "rebuild"
        elif strategy in ("pairwise", "kway"):
            chosen = strategy
        else:  # cost model: cheapest eligible strategy wins
            chosen = min(est, key=est.get)
            if len(ordered) == 2 and chosen == "kway":
                chosen = "pairwise"  # identical cost and walk at k = 2
        return ordered, {
            "strategy": chosen, "requested": strategy, "reason": reason,
            "est": est, "est_walk_steps": (
                kway_walk_steps(s.index.fm.length for s in ordered)
                if reason is None else 0
            ),
        }

    def _merge_run(self, run: list[Segment], strategy: str) -> Segment:
        """Fold one run of adjacent segments into a single segment,
        recording the planner's decision (and any rebuild fallback) in
        the compaction telemetry."""
        ordered, plan = self._plan_run(run, strategy)
        chosen = plan["strategy"]
        if plan["reason"] is not None and plan["requested"] != "rebuild":
            self.compact_fallbacks += 1
            self.compact_last_fallback_reason = plan["reason"]
            warnings.warn(
                f"compaction fell back to an O(n log n) rebuild: "
                f"{plan['reason']}", RuntimeWarning, stacklevel=3,
            )
        offset = min(s.offset for s in run)
        docs, toks = [], []
        for seg in ordered:
            base = seg.offset - offset
            docs.extend((ln, base + rs) for ln, rs in seg.docs)
            toks.append(seg.tokens)
        tokens = np.concatenate(toks)
        n_tokens = sum(s.n_tokens for s in run)

        fm = None
        if chosen == "kway":
            fm = merge_kway([s.index.fm for s in ordered],
                            compress_sa=self.compress_sa, pack=self.pack)
        elif chosen == "pairwise":
            acc = ordered[-1].index.fm
            for seg in reversed(ordered[:-1]):
                acc = merge_fm_indexes(seg.index.fm, acc,
                                       compress_sa=self.compress_sa,
                                       pack=self.pack)
            fm = acc
        plan["actual_walk_steps"] = (
            kway_walk_steps(s.index.fm.length for s in ordered)
            if fm is not None else 0
        )
        self.compact_last_plan = plan
        if fm is None:  # rebuild fallback/oracle: same text, same layout
            texts, sigmas = [], []
            for seg in ordered:
                for d in seg.doc_tokens():
                    s, sig = prepare_tokens(d, self.sample_rate, self.sigma,
                                            self.reserve_pad)
                    texts.append(s)
                    sigmas.append(sig)
            index = build_index_prepared(
                np.concatenate(texts), max(sigmas),
                sample_rate=self.sample_rate, sa_config=self.sa_config,
                sa_sample_rate=self.sa_sample_rate, pack=self.pack,
                compress_sa=self.compress_sa,
                text_length=sum(ln + 1 for ln, _ in docs),
            )
        else:
            index = SequenceIndex(
                fm, None, fm.bwt, fm.row, fm.sigma, fm.length,
                sum(ln + 1 for ln, _ in docs),
            )
        # counts completed merges only: a crash mid-merge leaves the
        # operands (and the counters) exactly as they were
        self.compact_strategy_counts[chosen] = (
            self.compact_strategy_counts.get(chosen, 0) + 1
        )
        return Segment(self._next_id_bump(), offset, n_tokens, index,
                       tokens, tuple(docs))

    def compact(self, min_tokens: int | None = None,
                strategy: str | None = None) -> int:
        """Fold runs of adjacent small segments into one segment each.

        Segments smaller than ``min_tokens`` (None = the constructor's
        ``segment_min_tokens`` default; every segment when that is also
        None) are grouped into maximal adjacent runs; each run of >= 2
        becomes a single segment.  Global coordinates are preserved (runs
        are adjacent) and **answers are invariant**: the merged segment
        indexes the same prepared documents, so no match appears or
        disappears — counts and in-k locate sets are bit-identical across
        the compact (the first-k *selection* for patterns with more than
        k occurrences follows SA order and may differ; see the module
        docstring).  Returns the number of merges performed.

        ``strategy``: "merge" (default, or the constructor's
        ``compact_strategy``) lets the cost model pick the cheapest of the
        k-way interleave walk, the pairwise fold, and the rebuild per run
        (``_plan_run``); "kway"/"pairwise" force one merge flavor; all
        three fall back to a rebuild — counted in ``compact_fallbacks``
        and warned about — for ineligible runs (distributed segments,
        mixed layouts, SA stride not dividing a non-last member's text,
        context-order-unsafe document tails); "rebuild" forces the
        raw-token rebuild — the merge paths' bit-identity oracle.  A live
        stacked fan-out catalog is updated incrementally
        (``fm_index.stacked_replace_run``) instead of being re-assembled
        from scratch.
        """
        if strategy is None:
            strategy = self.compact_strategy
        if strategy not in COMPACT_STRATEGIES:
            raise ValueError(f"unknown compact strategy {strategy!r}")
        if min_tokens is None:
            min_tokens = self.segment_min_tokens
        merged, out, run = 0, [], []
        replaces = []  # (old_start_idx, run_len) per merge, in order
        idx = 0

        def close_run():
            nonlocal merged
            if len(run) >= 2:
                out.append(self._merge_run(run, strategy))
                replaces.append((idx - len(run), len(run)))
                merged += 1
            else:
                out.extend(run)
            run.clear()

        for seg in self.segments:
            if min_tokens is None or seg.n_tokens < min_tokens:
                run.append(seg)
            else:
                close_run()
                out.append(seg)
            idx += 1
        close_run()
        self.segments = out
        self._update_stacked_after_compact(replaces, out)
        return merged

    def _update_stacked_after_compact(self, replaces, out) -> None:
        """Incrementally patch the stacked catalog for each merged run
        (indices shift as earlier runs collapse); any misfit (merged
        segment larger than the block bucket) drops the cache for a lazy
        full re-stack."""
        st = self._stacked_cache
        if not isinstance(st, StackedFMIndex) or not replaces:
            if replaces:
                self._stacked_cache = None
            return
        shift = 0  # earlier runs collapse len -> 1, shifting later indices
        try:
            for start, length in replaces:
                st = stacked_replace_run(
                    st, start - shift, length, out[start - shift].index.fm
                )
                shift += length - 1
        except (ValueError, AttributeError):
            self._stacked_cache = None
            return
        self._stacked_cache = st

    def maybe_compact(self, strategy: str | None = None) -> int:
        """Run ``compact`` when the background policy triggers.

        The trigger is cost-based: for each maximal adjacent run of >= 2
        segments below ``segment_min_tokens``, compact fires when the
        cheapest estimated merge strategy (``_est_costs``) costs at most
        ``compact_trigger_cost_ratio`` of the estimated rebuild — i.e.
        when the rebuild-free paths actually pay for themselves — OR when
        the run is so small that re-sorting it costs no more than one
        merge's fixed dispatch overhead (deferring such a run can never
        pay: any future merge of it costs at least that dispatch, so it
        compacts immediately, usually via the rebuild) — OR when the run
        has grown to ``compact_max_small`` segments (a backstop so
        per-query fan-out overhead cannot accumulate unboundedly while
        the cost model keeps deferring).  Estimates use only run sizes
        and counts; the exact eligibility checks (layout, context-order
        safety) happen at execute time in ``_plan_run``.  The serving
        path calls this after appends, so steady-state serving pays
        O(merge) per compaction, never O(corpus) of sorting.  Returns
        merges performed (0 when the trigger does not fire)."""
        mt = self.segment_min_tokens
        if mt is None or len(self.segments) < 2:
            return 0
        run: list[Segment] = []
        runs: list[list[Segment]] = []
        for seg in self.segments:
            if seg.n_tokens < mt:
                run.append(seg)
            elif run:
                runs.append(run)
                run = []
        if run:
            runs.append(run)
        for r in runs:
            if len(r) < 2:
                continue
            if len(r) >= self.compact_max_small:
                return self.compact(strategy=strategy)
            est = self._est_costs(sorted(r, key=lambda s: -s.n_tokens))
            best = min(est["pairwise"], est["kway"])
            if (best <= self.compact_trigger_cost_ratio * est["rebuild"]
                    or est["rebuild"] <= self.compact_cost_merge_us * 1e3):
                return self.compact(strategy=strategy)
        return 0

    def _next_id_bump(self) -> int:
        i = self._next_id
        self._next_id += 1
        return i

    # -- queries -------------------------------------------------------------

    def _stacked(self):
        """The stacked bucket layout for segment-parallel fan-out, or None
        when the sequential path applies (parallel=False, < 2 segments, or
        an unstackable mixed catalog under parallel=None).  Cached; append
        and compact patch the cache in place when the bucket fits and
        invalidate otherwise.  Bucket shapes are powers of two, so even a
        full rebuild after an append usually re-hits the same jit programs.
        """
        if self.parallel is False or not self.segments:
            return None
        if self.parallel is None and len(self.segments) < 2:
            return None
        if self._stacked_cache is None:
            try:
                self._stacked_cache = stack_fm_indexes(
                    [s.index.fm for s in self.segments]
                )
            except ValueError:
                if self.parallel:
                    raise
                self._stacked_cache = False  # unstackable: remember that
        return self._stacked_cache or None

    def count(self, patterns) -> np.ndarray:
        """Exact-match counts for int32[B, L] PAD-padded patterns: the sum
        of independent per-segment counts (int64[B]).

        With segment-parallel fan-out (``parallel``, default auto) all
        segments are answered by ONE stacked kernel dispatch per
        backward-search step instead of a per-segment Python loop —
        bit-identical per-segment counts, so an identical sum."""
        patterns = np.asarray(patterns, np.int32)
        st = self._stacked()
        if st is not None:
            per = np.asarray(count_stacked(st, patterns), np.int64)
            return per[: int(st.n_seg)].sum(axis=0)
        total = np.zeros(patterns.shape[0], np.int64)
        for seg in self.segments:
            total += np.asarray(seg.index.count(patterns), np.int64)
        return total

    def _to_global(self, seg: Segment, pos: np.ndarray, used: np.ndarray,
                   fill: int) -> np.ndarray:
        """Map segment-text positions to global raw-token coordinates.

        Single-document segments shift by the segment offset; merged
        segments map piecewise through the document table (position ->
        owning prepared document -> that document's global raw start).
        Garbage lanes (``~used``) resolve to ``fill``.
        """
        if not seg.multi_doc:
            return np.where(used, pos + seg.offset, fill)
        r = self.sample_rate
        lens = np.fromiter((d[0] for d in seg.docs), np.int64)
        rels = np.fromiter((d[1] for d in seg.docs), np.int64)
        padded = -(-(lens + 1) // r) * r
        u_starts = np.concatenate([[0], np.cumsum(padded)[:-1]])
        p = np.clip(pos, 0, int(padded.sum()) - 1)
        d = np.searchsorted(u_starts, p, side="right") - 1
        g = seg.offset + rels[d] + (p - u_starts[d])
        return np.where(used, g, fill)

    def locate(self, patterns, k: int):
        """First-k *global* occurrence positions per pattern.

        Returns (positions int64[B, k] sorted ascending, ``coord_end``
        filling unused slots; counts int64[B] clipped to k).  The k kept
        positions are the k smallest global positions among per-segment
        candidates (each segment contributes its first k in SA order — the
        same selection rule as the monolithic index applied per segment).
        Fan-out is segment-parallel (one stacked dispatch) whenever
        ``parallel`` allows; the per-segment candidates are bit-identical
        to the sequential path, so the merged answer is too.
        """
        patterns = np.asarray(patterns, np.int32)
        st = self._stacked()
        if st is not None:
            pos_all, cnt_all = locate_stacked(st, patterns, k)
            pos_all = np.asarray(pos_all, np.int64)
            cnt_all = np.asarray(cnt_all, np.int64)
            per_seg = (
                (pos_all[i], cnt_all[i]) for i in range(int(st.n_seg))
            )
        else:
            per_seg = (
                tuple(np.asarray(a, np.int64)
                      for a in seg.index.locate(patterns, k))
                for seg in self.segments
            )
        B = patterns.shape[0]
        fill = self.coord_end
        cand = [np.full((B, 1), fill, np.int64)]
        counts = np.zeros(B, np.int64)
        for seg, (pos, cnt) in zip(self.segments, per_seg):
            # only the first cnt[b] slots hold real (segment-local) positions
            used = np.arange(k)[None, :] < cnt[:, None]
            cand.append(self._to_global(seg, pos, used, fill))
            counts += cnt
        allpos = np.sort(np.concatenate(cand, axis=1), axis=1)[:, :k]
        if allpos.shape[1] < k:
            allpos = np.pad(allpos, ((0, 0), (0, k - allpos.shape[1])),
                            constant_values=fill)
        return allpos, np.minimum(counts, k)

    # -- lifecycle -----------------------------------------------------------

    def catalog(self) -> list[dict]:
        """JSON-able summary of the segment layout (id, offset, size,
        document table)."""
        return [
            {"seg_id": s.seg_id, "offset": s.offset, "n_tokens": s.n_tokens,
             "docs": [list(d) for d in s.docs]}
            for s in self.segments
        ]

    def _catalog_payload(self) -> dict:
        return {
            "format": CATALOG_FORMAT, "version": CATALOG_VERSION,
            "sigma": self.sigma, "sample_rate": self.sample_rate,
            "sa_sample_rate": self.sa_sample_rate,
            "pack": self.pack, "compress_sa": self.compress_sa,
            "reserve_pad": self.reserve_pad,
            "segment_min_tokens": self.segment_min_tokens,
            "compact_strategy": self.compact_strategy,
            "compact_trigger_ratio": self.compact_trigger_ratio,
            "compact_max_small": self.compact_max_small,
            "compact_fallbacks": self.compact_fallbacks,
            "compact_last_fallback_reason": self.compact_last_fallback_reason,
            "sa_config": self.sa_config._asdict(),
            "next_id": self._next_id, "next_offset": self.coord_end,
            "segments": self.catalog(),
        }

    @staticmethod
    def _seg_relpaths(directory: str, name: str) -> list[str]:
        """Every file of one segment directory, as "/"-joined relpaths."""
        out = []
        for root, _, names in os.walk(os.path.join(directory, name)):
            for fn in names:
                rel = os.path.relpath(os.path.join(root, fn), directory)
                out.append(rel.replace(os.sep, "/"))
        return sorted(out)

    def save(self, directory: str) -> None:
        """Persist catalog + every segment as one crash-safe **generation
        commit** (see ``core.journal``).

        Incremental: segments are immutable and ids never reused, so a
        segment directory that already exists is skipped (its checksums are
        carried over from the previous committed generation), and
        directories orphaned by ``compact`` are garbage-collected only
        *after* the new generation's pointer flip — a crash at any point
        of the save leaves the previous generation fully loadable, with
        recovery sweeping any staged debris on the next load.
        """
        from .index_io import save_index

        os.makedirs(directory, exist_ok=True)
        journal = GenerationJournal(directory)
        prev = journal.committed()
        prev_files = prev["files"] if prev else {}

        # phase 1 — stage: write + fsync every new artifact; nothing the
        # committed generation references is touched
        files: dict[str, dict] = {}
        for seg in self.segments:
            name = f"seg_{seg.seg_id:06d}"
            seg_dir = os.path.join(directory, name)
            fresh = not os.path.exists(os.path.join(seg_dir, "tokens.npz"))
            if fresh:
                save_index(seg_dir, seg.index)
                buf = io.BytesIO()
                np.savez(buf, tokens=seg.tokens)
                write_file_durable(os.path.join(seg_dir, "tokens.npz"),
                                   buf.getvalue())
            for rel in self._seg_relpaths(directory, name):
                if not fresh and rel in prev_files:
                    files[rel] = prev_files[rel]  # immutable: CRC carries
                else:
                    if fresh and not rel.endswith("tokens.npz"):
                        fsync_path(os.path.join(directory, rel))
                    files[rel] = manifest_entry(directory, rel)

        # phase 2 — commit: durable generation manifest, atomic pointer
        journal.commit(self._catalog_payload(), files)

        # post-commit: legacy-readable mirror + garbage collection of
        # orphaned segments, older generations, and staging debris
        write_file_durable(
            os.path.join(directory, "catalog.json"),
            json.dumps(self._catalog_payload(), indent=2).encode(),
        )
        journal.collect_garbage(files)

    @classmethod
    def load(cls, directory: str, **kwargs) -> "SegmentedIndex":
        """Restore a saved segmented index (single-device segments).

        Reads the **committed generation** (journal pointer; a torn save is
        rolled back to the last committed one and its staged debris swept),
        verifies every artifact's CRC32 against the generation manifest,
        and restores the healthy segments bit-identically via ``index_io``.
        A segment that fails verification or restore is **quarantined**
        (moved under ``quarantine/``, listed in ``self.quarantined``)
        instead of failing the load: the catalog comes up degraded but
        serving.  Build knobs come back from the catalog so future appends
        build segments exactly like the saved ones; ``kwargs`` override
        any of them.  Pre-journal directories (bare ``catalog.json``) load
        unverified, as before.
        """
        from .index_io import IndexIOError, restore_index

        journal = GenerationJournal(directory)
        man = journal.committed()
        if man is not None:
            cat, files = man["catalog"], man["files"]
            journal.collect_garbage(files)  # recovery: sweep torn saves
        else:  # legacy layout: unverified catalog.json
            with open(os.path.join(directory, "catalog.json")) as f:
                cat = json.load(f)
            files = None
        if cat.get("format") != CATALOG_FORMAT:
            raise ValueError(f"not a segment catalog: {directory}")
        if cat.get("version", 0) > CATALOG_VERSION:
            raise ValueError(
                f"catalog version {cat['version']} > supported "
                f"{CATALOG_VERSION}"
            )
        knobs = dict(
            sample_rate=cat["sample_rate"],
            sa_sample_rate=cat["sa_sample_rate"],
            pack=cat.get("pack"), compress_sa=cat.get("compress_sa"),
            reserve_pad=cat.get("reserve_pad"),
            segment_min_tokens=cat.get("segment_min_tokens"),
            compact_strategy=cat.get("compact_strategy", "merge"),
            compact_trigger_ratio=cat.get("compact_trigger_ratio", 0.5),
            compact_max_small=cat.get("compact_max_small", 8),
            sa_config=DistSAConfig(**cat.get(
                "sa_config", DistSAConfig()._asdict()
            )),
        )
        knobs.update(kwargs)
        self = cls(cat["sigma"], **knobs)
        self._next_id = cat["next_id"]
        # fallback telemetry survives restarts (additive keys; old catalogs
        # restore to the zero state)
        self.compact_fallbacks = int(cat.get("compact_fallbacks", 0))
        self.compact_last_fallback_reason = cat.get(
            "compact_last_fallback_reason"
        )
        for ent in cat["segments"]:
            name = f"seg_{ent['seg_id']:06d}"
            seg_dir = os.path.join(directory, name)
            reason = None
            if files is not None:
                rels = [r for r in files if r.startswith(name + "/")]
                if not rels:
                    reason = "no files recorded in the generation manifest"
                for rel in rels:
                    err = verify_file(directory, rel, files[rel])
                    if err:
                        reason = f"{rel}: {err}"
                        break
            if reason is None:
                try:
                    index = restore_index(seg_dir)
                    with np.load(os.path.join(seg_dir, "tokens.npz")) as z:
                        tokens = z["tokens"]
                    if len(tokens) != ent["n_tokens"]:
                        reason = (f"tokens.npz holds {len(tokens)} tokens, "
                                  f"catalog says {ent['n_tokens']}")
                except (IndexIOError, OSError, KeyError, ValueError) as e:
                    reason = f"restore failed: {e}"
            if reason is not None:
                journal.quarantine(name)
                self.quarantined.append({**ent, "reason": reason})
                continue
            self.segments.append(Segment(
                ent["seg_id"], ent["offset"], ent["n_tokens"], index,
                tokens, tuple(tuple(d) for d in ent.get("docs", []))
                or ((ent["n_tokens"], 0),),
            ))
        ends = [e["offset"] + e["n_tokens"]
                for e in cat["segments"]] + [cat.get("next_offset", 0)]
        self._next_offset = max(ends, default=0)
        return self
