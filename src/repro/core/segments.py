"""Segmented incremental append: grow an index without a full rebuild.

The paper builds one monolithic index per dataset; Sirén's *BWT for
terabases* and the authors' follow-up *BWT on a Large Scale* instead build
large BWTs from per-chunk structures that are merged — the natural shape
for an index that must grow with its corpus.  This module is the query-time
variant of that idea (LSM-tree style, as in Lucene-like search systems):

* ``append(tokens)`` builds a *new per-segment FM-index* over just the new
  text with the PR 2 fast builder — O(new segment), not O(corpus).
* ``count`` sums per-segment counts (each an independent, embarrassingly
  parallel backward search).
* ``locate`` offsets per-segment positions by the segment's global offset
  and merges the candidate sets.
* ``compact`` folds runs of small adjacent segments into one rebuilt
  segment, bounding per-query fan-out — the background-merge half of the
  LSM playbook.

Boundary semantics: a segment boundary is a *document* boundary.  Matches
never span segments, exactly as matches never span the documents of a
concatenated collection; relative to one monolithic index over the raw
concatenation, the segmented answer differs only by occurrences crossing a
segment boundary (and ``compact`` can only re-introduce those inside a
merged run).  ``tests/test_segments.py`` asserts this equivalence exactly:
segmented count == monolithic count − cross-boundary occurrences.

All segments share one declared alphabet (``sigma``), so every segment's
pad token sorts above every real token of *any* segment and a query over
the global alphabet can never match padding (see
``pipeline.prepare_tokens``).
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil

import numpy as np

from .dist_suffix_array import DistSAConfig
from .fm_index import count_stacked, locate_stacked, stack_fm_indexes
from .pipeline import SequenceIndex, build_index

CATALOG_FORMAT = "segmented_index_catalog"
CATALOG_VERSION = 1


@dataclasses.dataclass
class Segment:
    """One immutable index segment plus its placement in global coordinates."""

    seg_id: int
    offset: int            # global position of this segment's first token
    n_tokens: int          # raw appended tokens (no sentinel, no padding)
    index: SequenceIndex
    tokens: np.ndarray     # retained corpus slice — compact() rebuild input


class SegmentedIndex:
    """An FM-index over a growing corpus, as a catalog of immutable segments.

    ``sigma`` declares the global alphabet: all appended tokens must lie in
    [1, sigma).  Build knobs (``sample_rate``, ``sa_sample_rate``,
    ``sa_config``, ``pack``, ``compress_sa``) apply to every segment build.
    Query interface (``count`` / ``locate``) matches ``SequenceIndex``, so
    ``serving.engine.FMQueryServer`` serves a segmented index unchanged.
    """

    def __init__(self, sigma: int, *, sample_rate: int = 64,
                 sa_sample_rate: int = 32,
                 sa_config: DistSAConfig = DistSAConfig(),
                 pack: bool | None = None, compress_sa: bool | None = None,
                 segment_min_tokens: int | None = None,
                 parallel: bool | None = None):
        if sigma < 2:
            raise ValueError("sigma must cover at least one real token")
        self.sigma = sigma
        self.sample_rate = sample_rate
        self.sa_sample_rate = sa_sample_rate
        self.sa_config = sa_config
        self.pack = pack
        self.compress_sa = compress_sa
        self.segment_min_tokens = segment_min_tokens  # compact() default
        # segment-parallel query fan-out: None = auto (stacked dispatch
        # whenever >= 2 stackable segments), False = always sequential,
        # True = require the stacked path (raise if segments can't stack)
        self.parallel = parallel
        self.segments: list[Segment] = []
        self._next_id = 0
        self._stacked_cache: object | None = None

    @classmethod
    def from_config(cls, sigma: int, cfg) -> "SegmentedIndex":
        """Build from a BWTIndexConfig's index/lifecycle knobs (the config's
        own ``sigma`` describes the full byte workload; segmented corpora
        pass their actual alphabet)."""
        return cls(
            sigma, sample_rate=cfg.sample_rate,
            sa_sample_rate=cfg.sa_sample_rate,
            sa_config=DistSAConfig(
                engine=cfg.engine, capacity_factor=cfg.capacity_factor,
                qgram=cfg.qgram, qgram_words=cfg.qgram_words,
                discard=cfg.discard, local_sort=cfg.local_sort,
            ),
            pack=cfg.pack, compress_sa=cfg.compress_sa,
            segment_min_tokens=cfg.segment_min_tokens,
            parallel=cfg.serve_parallel_segments,
        )

    # -- growth --------------------------------------------------------------

    @property
    def total_tokens(self) -> int:
        return sum(s.n_tokens for s in self.segments)

    def _build(self, tokens: np.ndarray) -> SequenceIndex:
        return build_index(
            tokens, sample_rate=self.sample_rate,
            sa_config=self.sa_config, sa_sample_rate=self.sa_sample_rate,
            pack=self.pack, sigma=self.sigma, compress_sa=self.compress_sa,
        )

    def append(self, tokens) -> Segment:
        """Index new text as a fresh segment; O(len(tokens)) work.

        ``tokens`` int32[m] in [1, sigma).  The new segment occupies global
        positions [total_tokens, total_tokens + m).
        """
        tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
        if tokens.size == 0:
            raise ValueError("cannot append an empty segment")
        if tokens.min() < 1 or tokens.max() >= self.sigma:
            raise ValueError(
                f"tokens out of declared alphabet [1, {self.sigma})"
            )
        seg = Segment(self._next_id, self.total_tokens, len(tokens),
                      self._build(tokens), tokens)
        self._next_id += 1
        self.segments.append(seg)
        self._stacked_cache = None
        return seg

    def compact(self, min_tokens: int | None = None) -> int:
        """Merge runs of adjacent small segments into one via rebuild.

        Segments smaller than ``min_tokens`` (None = the constructor's
        ``segment_min_tokens`` default; every segment when that is also
        None) are grouped into maximal adjacent runs; each run of >= 2 rebuilds as a
        single segment over the concatenated run text.  Global coordinates
        are preserved (runs are adjacent).  Returns the number of merges
        performed.  Within a merged run, matches spanning the old internal
        boundaries become visible — compaction only moves the answer
        *closer* to the monolithic one.
        """
        if min_tokens is None:
            min_tokens = self.segment_min_tokens
        merged, out, run = 0, [], []

        def close_run():
            nonlocal merged
            if len(run) >= 2:
                toks = np.concatenate([s.tokens for s in run])
                out.append(Segment(self._next_id_bump(), run[0].offset,
                                   len(toks), self._build(toks), toks))
                merged += 1
            else:
                out.extend(run)
            run.clear()

        for seg in self.segments:
            if min_tokens is None or seg.n_tokens < min_tokens:
                run.append(seg)
            else:
                close_run()
                out.append(seg)
        close_run()
        self.segments = out
        self._stacked_cache = None
        return merged

    def _next_id_bump(self) -> int:
        i = self._next_id
        self._next_id += 1
        return i

    # -- queries -------------------------------------------------------------

    def _stacked(self):
        """The stacked bucket layout for segment-parallel fan-out, or None
        when the sequential path applies (parallel=False, < 2 segments, or
        an unstackable mixed catalog under parallel=None).  Cached; append
        and compact invalidate.  Bucket shapes are powers of two, so the
        cache rebuild after an append usually re-hits the same jit programs.
        """
        if self.parallel is False or not self.segments:
            return None
        if self.parallel is None and len(self.segments) < 2:
            return None
        if self._stacked_cache is None:
            try:
                self._stacked_cache = stack_fm_indexes(
                    [s.index.fm for s in self.segments]
                )
            except ValueError:
                if self.parallel:
                    raise
                self._stacked_cache = False  # unstackable: remember that
        return self._stacked_cache or None

    def count(self, patterns) -> np.ndarray:
        """Exact-match counts for int32[B, L] PAD-padded patterns: the sum
        of independent per-segment counts (int64[B]).

        With segment-parallel fan-out (``parallel``, default auto) all
        segments are answered by ONE stacked kernel dispatch per
        backward-search step instead of a per-segment Python loop —
        bit-identical per-segment counts, so an identical sum."""
        patterns = np.asarray(patterns, np.int32)
        st = self._stacked()
        if st is not None:
            per = np.asarray(count_stacked(st, patterns), np.int64)
            return per[: int(st.n_seg)].sum(axis=0)
        total = np.zeros(patterns.shape[0], np.int64)
        for seg in self.segments:
            total += np.asarray(seg.index.count(patterns), np.int64)
        return total

    def locate(self, patterns, k: int):
        """First-k *global* occurrence positions per pattern.

        Returns (positions int64[B, k] sorted ascending, ``total_tokens``
        filling unused slots; counts int64[B] clipped to k).  The k kept
        positions are the k smallest global positions among per-segment
        candidates (each segment contributes its first k in SA order — the
        same selection rule as the monolithic index applied per segment).
        Fan-out is segment-parallel (one stacked dispatch) whenever
        ``parallel`` allows; the per-segment candidates are bit-identical
        to the sequential path, so the merged answer is too.
        """
        patterns = np.asarray(patterns, np.int32)
        st = self._stacked()
        if st is not None:
            pos_all, cnt_all = locate_stacked(st, patterns, k)
            pos_all = np.asarray(pos_all, np.int64)
            cnt_all = np.asarray(cnt_all, np.int64)
            per_seg = (
                (pos_all[i], cnt_all[i]) for i in range(int(st.n_seg))
            )
        else:
            per_seg = (
                tuple(np.asarray(a, np.int64)
                      for a in seg.index.locate(patterns, k))
                for seg in self.segments
            )
        B = patterns.shape[0]
        fill = self.total_tokens
        cand = [np.full((B, 1), fill, np.int64)]
        counts = np.zeros(B, np.int64)
        for seg, (pos, cnt) in zip(self.segments, per_seg):
            # only the first cnt[b] slots hold real (segment-local) positions
            used = np.arange(k)[None, :] < cnt[:, None]
            cand.append(np.where(used, pos + seg.offset, fill))
            counts += cnt
        allpos = np.sort(np.concatenate(cand, axis=1), axis=1)[:, :k]
        if allpos.shape[1] < k:
            allpos = np.pad(allpos, ((0, 0), (0, k - allpos.shape[1])),
                            constant_values=fill)
        return allpos, np.minimum(counts, k)

    # -- lifecycle -----------------------------------------------------------

    def catalog(self) -> list[dict]:
        """JSON-able summary of the segment layout (id, offset, size)."""
        return [
            {"seg_id": s.seg_id, "offset": s.offset, "n_tokens": s.n_tokens}
            for s in self.segments
        ]

    def save(self, directory: str) -> None:
        """Persist catalog + every segment (index checkpoint AND raw tokens,
        so a restored catalog can keep compacting).

        Incremental: segments are immutable and ids never reused, so a
        segment directory that already exists is skipped, and directories
        orphaned by ``compact`` (no longer in the catalog) are deleted —
        repeated append/compact/save cycles cost O(new segments) IO and the
        directory tracks the live catalog exactly.
        """
        from .index_io import save_index

        os.makedirs(directory, exist_ok=True)
        live = set()
        for seg in self.segments:
            name = f"seg_{seg.seg_id:06d}"
            live.add(name)
            seg_dir = os.path.join(directory, name)
            if os.path.exists(os.path.join(seg_dir, "tokens.npz")):
                continue  # immutable + id-unique -> already persisted
            save_index(seg_dir, seg.index)
            np.savez(os.path.join(seg_dir, "tokens.npz"), tokens=seg.tokens)
        for name in os.listdir(directory):
            if name.startswith("seg_") and name not in live:
                shutil.rmtree(os.path.join(directory, name))
        cat = {
            "format": CATALOG_FORMAT, "version": CATALOG_VERSION,
            "sigma": self.sigma, "sample_rate": self.sample_rate,
            "sa_sample_rate": self.sa_sample_rate,
            "pack": self.pack, "compress_sa": self.compress_sa,
            "segment_min_tokens": self.segment_min_tokens,
            "sa_config": self.sa_config._asdict(),
            "next_id": self._next_id, "segments": self.catalog(),
        }
        tmp = os.path.join(directory, "catalog.json.tmp")
        with open(tmp, "w") as f:
            json.dump(cat, f, indent=2)
        os.replace(tmp, os.path.join(directory, "catalog.json"))

    @classmethod
    def load(cls, directory: str, **kwargs) -> "SegmentedIndex":
        """Restore a saved segmented index (single-device segments).

        Build knobs (sample_rate, pack, compress_sa, sa_config, ...) come
        back from the catalog, so future appends/compactions build segments
        exactly like the saved ones; ``kwargs`` override any of them.
        Existing segments restore bit-identically via ``index_io``.
        """
        from .index_io import restore_index

        with open(os.path.join(directory, "catalog.json")) as f:
            cat = json.load(f)
        if cat.get("format") != CATALOG_FORMAT:
            raise ValueError(f"not a segment catalog: {directory}")
        if cat.get("version", 0) > CATALOG_VERSION:
            raise ValueError(
                f"catalog version {cat['version']} > supported "
                f"{CATALOG_VERSION}"
            )
        knobs = dict(
            sample_rate=cat["sample_rate"],
            sa_sample_rate=cat["sa_sample_rate"],
            pack=cat.get("pack"), compress_sa=cat.get("compress_sa"),
            segment_min_tokens=cat.get("segment_min_tokens"),
            sa_config=DistSAConfig(**cat.get(
                "sa_config", DistSAConfig()._asdict()
            )),
        )
        knobs.update(kwargs)
        self = cls(cat["sigma"], **knobs)
        self._next_id = cat["next_id"]
        for ent in cat["segments"]:
            seg_dir = os.path.join(directory, f"seg_{ent['seg_id']:06d}")
            index = restore_index(seg_dir)
            with np.load(os.path.join(seg_dir, "tokens.npz")) as z:
                tokens = z["tokens"]
            assert len(tokens) == ent["n_tokens"], seg_dir
            self.segments.append(Segment(ent["seg_id"], ent["offset"],
                                         ent["n_tokens"], index, tokens))
        return self
