"""The paper's competitor: Menon, Bhat & Schatz, "Rapid parallel genome
indexing with MapReduce" (MapReduce'11) — reimplemented in JAX, as the paper
reimplemented it in Spark ("put in equal terms", §3).

Their construction partitions the suffix array into ranges via sampled
splitters and sorts each range by DIRECT suffix comparisons (no prefix
doubling).  The JAX adaptation keeps the cost structure honest:

  * range partitioning == the first sort pass over a K-char prefix key;
  * direct string comparison == iterative K-char "prefix tupling": each
    pass gathers the NEXT K characters for still-tied suffixes and re-sorts
    within tie groups.  Passes needed ~ LCP_max / K, versus ceil(log2 n)
    doubling rounds for the paper's algorithm — which is exactly the
    scaling gap Table 2 demonstrates (repetitive inputs explode the LCP).

``suffix_array_rpgi`` is used by benchmarks/table2_bwt.py as the competitor
column and is validated against the naive oracle in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


@functools.partial(jax.jit, static_argnames=("prefix_block", "max_passes"))
def suffix_array_rpgi(
    s: jax.Array, *, prefix_block: int = 8, max_passes: int = 4096
) -> jax.Array:
    """Suffix array via ranged direct-comparison sorting (competitor).

    ``s`` must be sentinel-terminated (token 0, unique, smallest).
    """
    n = s.shape[0]
    K = prefix_block
    idx = jnp.arange(n, dtype=jnp.int32)

    def gather_block(order, t):
        """chars [t*K, (t+1)*K) of each suffix in ``order`` (-1 past end)."""
        pos = order[:, None] + t * K + jnp.arange(K, dtype=jnp.int32)[None, :]
        chars = s[jnp.clip(pos, 0, n - 1)]
        return jnp.where(pos < n, chars, -1)                  # (n, K)

    def regroup(group, keys):
        """group heads after sorting by (group, keys): adjacent compare."""
        same = jnp.ones(n - 1, dtype=bool)
        same &= group[1:] == group[:-1]
        for k in range(K):
            same &= keys[1:, k] == keys[:-1, k]
        flags = jnp.concatenate([jnp.ones((1,), bool), ~same])
        heads = jnp.where(flags, idx, 0)
        return lax.associative_scan(jnp.maximum, heads), jnp.all(flags)

    # pass 0: range partitioning by the first K chars (splitter buckets)
    keys0 = gather_block(idx, 0)
    ops = lax.sort(
        tuple(keys0[:, k] for k in range(K)) + (idx,), num_keys=K
    )
    order = ops[-1]
    keys_sorted = jnp.stack(ops[:K], axis=1)
    group, done = regroup(jnp.zeros(n, jnp.int32), keys_sorted)

    def cond(state):
        _, _, done, t = state
        return (~done) & (t < max_passes)

    def body(state):
        order, group, _, t = state
        keys = gather_block(order, t)
        ops = lax.sort(
            (group,) + tuple(keys[:, k] for k in range(K)) + (order,),
            num_keys=K + 1,
        )
        new_order = ops[-1]
        keys_sorted = jnp.stack(ops[1 : K + 1], axis=1)
        new_group, done = regroup(ops[0], keys_sorted)
        return new_order, new_group, done, t + 1

    order, group, done, _ = lax.while_loop(
        cond, body, (order, group, done, jnp.int32(1))
    )
    return order


def bwt_rpgi(s: jax.Array):
    """Competitor end-to-end: SA by ranged direct sort, then the BWT join."""
    from .bwt import bwt_from_sa

    sa = suffix_array_rpgi(s)
    return bwt_from_sa(s, sa)
