"""Fused sort keys for the prefix-doubling build hot loop.

Every doubling round sorts ``(rank, rank[i+h])`` pairs with an index
payload.  The seed implementation passed three separate int32 operands to
``lax.sort(num_keys=2)``; every merge-exchange / shuffle round therefore
moved (and compared) three words per element.  This module packs the pair
into the minimum number of **uint32 key words** — one word whenever
``bits(rank) + bits(rank2+1) <= 32`` (holds for n <= 65535), two words
otherwise —
so the sort engines move one or two key operands plus one payload, and the
radix engine knows exactly how many significant bits each word carries.

Pad semantics (the unsigned replacement for the seed's signed int32 pad):

* Ranks are biased by +1 before packing so ``suffix_array.OVERFLOW_RANK``
  (-1, the "suffix shorter than h" marker) packs to field value 0 and keeps
  sorting *before* every real rank.
* Pad keys are **field-limited all-ones** (``(1 << field_bits) - 1`` per
  word), not ``0xFFFFFFFF``: the radix engine only sorts ``key_bits``
  significant bits, so a pad must stay maximal *within the field*.  For
  pair keys the all-ones pad is strictly greater than any real key (proof
  in ``PairSpec.pad_words``); q-gram keys can saturate the field, which is
  why ``dist_sort.samplesort_sharded`` breaks pad/real ties on a validity
  key instead of the key value.

Also here: the packed q-gram initialiser.  ``qgram_params`` picks
``q = floor(32 / ceil(log2 sigma))`` characters per uint32 word (10 for the
sigma=6 DNA corpora, 3 for byte text); ranking suffixes by that word
replaces the first ``ceil(log2 q)`` doubling rounds of the seed's
single-character Occ init.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class PairSpec(NamedTuple):
    """Static packing layout for (rank, rank2) pairs of a length-n text."""

    n: int        # ranks r1 in [0, n-1]; r2 in [-1, n-1] (biased +1 on pack)
    words: int    # key words (1 = fused single uint32, 2 = hi/lo uint32)
    r1_bits: int  # significant bits of the r1 field
    r2_bits: int  # significant bits of the biased r2 field

    @property
    def key_bits(self) -> tuple[int, ...]:
        """Significant bits per key word, most-significant word first."""
        if self.words == 1:
            return (self.r1_bits + self.r2_bits,)
        return (self.r1_bits, self.r2_bits)

    def pad_words(self) -> tuple[int, ...]:
        """Field-limited all-ones pad per word; sorts strictly after every
        real pair key.  (Strict: a real key would need r1 == 2^r1_bits - 1
        AND r2+1 == 2^r2_bits - 1, i.e. n-1 and n both all-ones, which no
        n >= 2 satisfies.)"""
        return tuple((1 << b) - 1 for b in self.key_bits)


def pair_spec(n: int) -> PairSpec:
    """Choose the packing for ranks of a length-``n`` text (static)."""
    if n < 2:
        return PairSpec(n, 1, 1, 1)
    r1_bits = (n - 1).bit_length()   # r1 <= n - 1
    r2_bits = n.bit_length()         # r2 + 1 <= n
    if r1_bits + r2_bits <= 32:
        return PairSpec(n, 1, r1_bits, r2_bits)
    return PairSpec(n, 2, r1_bits, r2_bits)


def pack_pairs(r1: jax.Array, r2: jax.Array, spec: PairSpec
               ) -> tuple[jax.Array, ...]:
    """(r1 int32 >= 0, r2 int32 >= -1) -> uint32 key words, MSW first."""
    hi = r1.astype(jnp.uint32)
    lo = (r2 + 1).astype(jnp.uint32)
    if spec.words == 1:
        return ((hi << spec.r2_bits) | lo,)
    return hi, lo


def unpack_pairs(words: tuple[jax.Array, ...], spec: PairSpec
                 ) -> tuple[jax.Array, jax.Array]:
    """Inverse of :func:`pack_pairs` (pad words unpack to garbage — callers
    mask by slot validity)."""
    if spec.words == 1:
        (w,) = words
        r1 = (w >> spec.r2_bits).astype(jnp.int32)
        r2 = (w & jnp.uint32((1 << spec.r2_bits) - 1)).astype(jnp.int32) - 1
        return r1, r2
    hi, lo = words
    return hi.astype(jnp.int32), lo.astype(jnp.int32) - 1


# ---------------------------------------------------------------------------
# packed q-gram init
# ---------------------------------------------------------------------------

def qgram_params(sigma: int, words: int = 2) -> tuple[int, int, int]:
    """(q, fields_per_word, bits_per_char) for a ``words``-word init key.

    Each uint32 word packs ``floor(32 / ceil(log2 sigma))`` characters; two
    words (a 64-bit logical key, the default) double q for one extra sort
    operand — measured on 64 KiB corpora this leaves <0.01% of suffixes
    ambiguous for DNA/proteins and ~54% (vs 98% single-word) for byte text.
    """
    bits = max(1, (max(2, sigma) - 1).bit_length())
    fpw = max(1, 32 // bits)
    return fpw * words, fpw, bits


def qgram_pad(fpw: int, bits: int) -> int:
    """Field-limited per-word pad for q-gram keys.  NOT strictly greater
    than every real key (a text of all max-chars saturates the field);
    engines break the tie on validity, and LSD-radix stability keeps
    appended pads last."""
    return (1 << (fpw * bits)) - 1


def qgram_rounds_skipped(q: int) -> int:
    """Doubling rounds (h = 1, 2, ..) the q-char init makes unnecessary."""
    return max(0, math.ceil(math.log2(q))) if q > 1 else 0


def qgram_keys_local(s: jax.Array, fpw: int, bits: int, words: int = 1
                     ) -> tuple[jax.Array, ...]:
    """uint32[n] key words per suffix (MSW first): the first ``words*fpw``
    chars packed big-endian, 0 (== sentinel) past the end.  Key order
    matches suffix order truncated to q chars with shorter-sorts-first
    semantics: past-end padding reuses the sentinel value, and the unique
    terminal sentinel makes the digit strings of two distinct
    end-overlapping suffixes differ.
    """
    n = s.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    out = []
    for w in range(words):
        v = jnp.zeros(n, jnp.uint32)
        for j in range(w * fpw, (w + 1) * fpw):
            c = jnp.where(idx + j < n, jnp.roll(s, -j), 0).astype(jnp.uint32)
            v = (v << bits) | c
        out.append(v)
    return tuple(out)
