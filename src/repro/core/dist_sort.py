"""Distributed sorting / scanning primitives for TPU meshes.

These functions are called INSIDE ``shard_map`` over a 1-D device axis
(``info.axis``) and operate on the local shard view.  They implement the
Spark-shuffle equivalents from DESIGN.md §4:

* ``bitonic_sort_sharded`` — Batcher bitonic merge-exchange across devices
  (deterministic buffer sizes, ``log²P`` ppermute rounds; the beyond-paper
  engine — SPMD-native, no capacity assumptions).
* ``samplesort_sharded`` — the paper-faithful range-partitioned sample sort:
  regular splitter sampling + capacity-bounded ``all_to_all`` shuffle.
  Overflow is reported, not hidden (Spark would spill; ICI cannot).
* ``exclusive_scan_sharded`` / ``exclusive_max_sharded`` — distributed
  exclusive scans of per-shard aggregates (the "offset of the previous
  partitions" of the paper's Re-Ranking step).
* ``shift_sharded`` — the distributed roll that implements the paper's
  "Shifting" map (rank[i + h]) with two neighbour ppermutes.

All collective permutations use static perms (ppermute requirement); the
prefix-doubling driver therefore unrolls over ``h`` (h is a static integer,
known per round).

Fused-key layout (PR 2): the doubling driver packs each (rank, rank[i+h])
pair into 1-2 **uint32 key words** (``core.keypack``), so both engines sort
one or two unsigned key operands plus an int32 index payload instead of
three int32 operands.  Consequences handled here:

* pads are per-dtype (``pad_value``) instead of the signed ``INT_PAD``, and
  a key word may legitimately saturate its field (packed q-gram keys), so
  the samplesort recombine step breaks pad/real ties on a validity key;
* local sorts dispatch through ``local_sort``/``key_bits`` to either
  ``lax.sort`` or the Pallas LSD radix engine (``kernels.ops.radix_sort``);
* ``samplesort_sharded`` takes ``n_valid_in`` so the discarding driver can
  mark already-unique suffixes as pad slots — they are excluded from
  sampling and never enter the all_to_all, shrinking shuffle traffic with
  the active fraction.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax

# COMPARE/RADIX and the local-sort dispatch (lax.sort vs the LSD radix
# pipeline over key_bits significant bits) live in kernels.ops — one
# implementation shared with the single-device builder
from ..kernels import ops as kernel_ops
from ..kernels.ops import COMPARE, RADIX  # noqa: F401  (re-export)


def pad_value(dtype) -> int:
    """Largest value of ``dtype`` — the pad key for unsigned/signed sorts.
    (The seed's signed int32 ``INT_PAD`` is this for int32; uint32 key
    words need 0xFFFFFFFF, which int32 comparison would order *first*.)"""
    return int(jnp.iinfo(jnp.dtype(dtype)).max)


class ShardInfo(NamedTuple):
    """Static description of the sharded 1-D array layout."""

    axis: str        # mesh axis name the array is sharded over
    parts: int       # number of shards P (must be a power of two for bitonic)
    part_size: int   # local elements m; global n = P * m

    @property
    def n(self) -> int:
        return self.parts * self.part_size


def _me(info: ShardInfo) -> jax.Array:
    return lax.axis_index(info.axis)


# ---------------------------------------------------------------------------
# distributed exclusive scans (per-shard aggregates)
# ---------------------------------------------------------------------------

def exclusive_scan_sharded(info: ShardInfo, local_agg: jax.Array) -> jax.Array:
    """Sum of ``local_agg`` over all devices with smaller axis index.

    ``local_agg`` may be scalar or have trailing dims (e.g. per-character
    count vectors for the distributed Occ table).
    """
    gathered = lax.all_gather(local_agg, info.axis)  # (P, ...)
    mask = jnp.arange(info.parts) < _me(info)
    mask = mask.reshape((info.parts,) + (1,) * (gathered.ndim - 1))
    return jnp.sum(jnp.where(mask, gathered, 0), axis=0)


def exclusive_max_sharded(
    info: ShardInfo, local_agg: jax.Array, identity: int = -1
) -> jax.Array:
    """Max of ``local_agg`` over devices with smaller axis index."""
    gathered = lax.all_gather(local_agg, info.axis)
    mask = jnp.arange(info.parts) < _me(info)
    mask = mask.reshape((info.parts,) + (1,) * (gathered.ndim - 1))
    return jnp.max(jnp.where(mask, gathered, identity), axis=0)


# ---------------------------------------------------------------------------
# distributed shift (the paper's "Shifting and Pairing" map)
# ---------------------------------------------------------------------------

def shift_sharded(
    info: ShardInfo, x: jax.Array, h: int, fill: int
) -> jax.Array:
    """out[g] = x[g + h] for global g, ``fill`` past the end.

    ``h`` is static (one prefix-doubling round = one power of two), so the
    ppermute perms are static: the data for any destination shard lives on at
    most two source shards (DESIGN.md §2 table, "distributed roll").
    """
    P, m = info.parts, info.part_size
    q, rs = divmod(h, m)
    if q >= P:  # the whole shard is past the end
        return jnp.full_like(x, fill)

    # I receive the shard of device (me + q); sender i sends to (i - q).
    perm_a = [(i, (i - q) % P) for i in range(P)]
    a = lax.ppermute(x, info.axis, perm_a) if q % P != 0 else x
    if rs == 0:
        out = a
    else:
        perm_b = [(i, (i - q - 1) % P) for i in range(P)]
        b = lax.ppermute(x, info.axis, perm_b)
        out = jnp.concatenate([a[rs:], b[:rs]])

    gidx = _me(info) * m + jnp.arange(m, dtype=jnp.int32)
    return jnp.where(gidx + h < info.n, out, fill)


# ---------------------------------------------------------------------------
# engine 1: bitonic merge-exchange
# ---------------------------------------------------------------------------

def _merge_split(
    info: ShardInfo,
    operands: tuple[jax.Array, ...],
    num_keys: int,
    j: int,
    keep_low: jax.Array,
    is_lower: jax.Array,
    engine: str,
    key_bits,
):
    """Exchange full shards with partner ``me ^ j``; keep low or high half of
    the merged 2m block.  Multiple key operands give the lexicographic order
    (avoids int64 key packing, which TPUs dislike — fused uint32 words from
    ``core.keypack`` arrive here as separate operands).

    Both partners must sort the SAME sequence: both local engines are
    stable, so with tied keys the payload order depends on concatenation
    order.  Canonical order = lower device's shard first on both sides,
    which makes the kept halves exactly complementary."""
    m = info.part_size
    perm = [(i, i ^ j) for i in range(info.parts)]
    received = tuple(lax.ppermute(x, info.axis, perm) for x in operands)
    merged = kernel_ops.local_sort(
        tuple(
            jnp.concatenate(
                [jnp.where(is_lower, a, b), jnp.where(is_lower, b, a)]
            )
            for a, b in zip(operands, received)
        ),
        num_keys, engine=engine, key_bits=key_bits,
    )
    start = jnp.where(keep_low, 0, m)
    return tuple(lax.dynamic_slice_in_dim(x, start, m) for x in merged)


def bitonic_sort_sharded(
    info: ShardInfo,
    operands: Sequence[jax.Array],
    num_keys: int = 1,
    *,
    local_sort: str = COMPARE,
    key_bits=None,
) -> tuple[jax.Array, ...]:
    """Globally sort sharded arrays lexicographically by the first
    ``num_keys`` operands; remaining operands are payloads carried along.

    Returns shards of the globally sorted sequence (device d holds global
    positions [d*m, (d+1)*m)) — deterministic sizes, no capacity bounds.
    """
    P = info.parts
    if P & (P - 1):
        raise ValueError(f"bitonic engine needs power-of-two parts, got {P}")
    operands = kernel_ops.local_sort(operands, num_keys, engine=local_sort,
                                     key_bits=key_bits)
    me = _me(info)
    k = 2
    while k <= P:
        j = k // 2
        while j >= 1:
            partner = me ^ j
            ascending = (me & k) == 0
            is_lower = me < partner
            keep_low = is_lower == ascending
            operands = _merge_split(
                info, operands, num_keys, j, keep_low, is_lower,
                local_sort, key_bits,
            )
            j //= 2
        k *= 2
    return operands


def scatter_to_index_bitonic(
    info: ShardInfo, gidx: jax.Array, values: tuple[jax.Array, ...],
    *, local_sort: str = COMPARE,
) -> tuple[jax.Array, ...]:
    """Route (gidx, values) so device d ends up with values for global
    indices [d*m, (d+1)*m) in order.  ``gidx`` must be a permutation of
    0..n-1, hence sorting by it is a deterministic all-to-all."""
    kb = (max(1, info.n - 1).bit_length(),)
    sorted_ops = bitonic_sort_sharded(
        info, (gidx, *values), num_keys=1, local_sort=local_sort, key_bits=kb
    )
    return sorted_ops[1:]


# ---------------------------------------------------------------------------
# engine 2: sample sort (paper-faithful range shuffle)
# ---------------------------------------------------------------------------

def _lex_less(a: tuple, b: tuple):
    """Elementwise lexicographic a < b over parallel key arrays."""
    lt = jnp.zeros(jnp.broadcast_shapes(a[0].shape, b[0].shape), dtype=bool)
    eq = jnp.ones_like(lt)
    for x, y in zip(a, b):
        lt = lt | (eq & (x < y))
        eq = eq & (x == y)
    return lt


def _lex_searchsorted(sorted_keys: tuple, queries: tuple) -> jax.Array:
    """searchsorted(side='left') for multi-key arrays: position of the first
    sorted element not less than the query.  Binary search, vmapped over
    queries."""
    m = sorted_keys[0].shape[0]
    steps = max(1, (m - 1).bit_length())

    def one(q):
        # derive the carry from varying data so shard_map's varying-manual-
        # axes check accepts the fori_loop (constants would be unvarying)
        zero = (q[0] * 0).astype(jnp.int32)
        lo = zero
        hi = zero + m

        def body(_, state):
            lo, hi = state
            mid = (lo + hi) // 2
            key_mid = tuple(k[jnp.minimum(mid, m - 1)] for k in sorted_keys)
            # freeze once converged: extra fori iterations after lo == hi
            # must not move the bounds (they once pushed lo past m, which
            # made the capacity clip send one element twice — caught by the
            # non-power-of-two device-count test)
            active = lo < hi
            go_right = _lex_less(key_mid, q)
            new_lo = jnp.where(active & go_right, mid + 1, lo)
            new_hi = jnp.where(active & ~go_right, mid, hi)
            return new_lo, new_hi

        lo, hi = lax.fori_loop(0, steps + 1, body, (lo, hi))
        return lo

    return jax.vmap(one)(queries)


class SampleSortResult(NamedTuple):
    operands: tuple[jax.Array, ...]  # local slots, valid entries sorted first
    n_valid: jax.Array               # scalar: valid slots on this device
    overflow: jax.Array              # scalar bool: capacity exceeded anywhere


def samplesort_sharded(
    info: ShardInfo,
    operands: Sequence[jax.Array],
    num_keys: int = 1,
    capacity_factor: float = 2.0,
    *,
    key_pads: Sequence[int] | None = None,
    n_valid_in: jax.Array | None = None,
    local_sort: str = COMPARE,
    key_bits=None,
) -> SampleSortResult:
    """Paper's range-partitioned sort: sample splitters, range-shuffle via
    capacity-bounded all_to_all, sort locally.

    The global order is: all valid elements of device 0, then device 1, ...
    (within a device, valid slots are sorted and padded slots follow).
    Capacity per (src, dst) bucket is ``ceil(capacity_factor * m / P)``;
    overflow sets the flag (driver retries with larger factor — the explicit
    version of Spark's skew handling).

    ``key_pads`` is the per-key pad value (defaults to the dtype max; fused
    uint32 key words pass their field-limited pad from ``core.keypack``).  A
    real key may equal the pad (saturated q-gram fields), so the recombine
    sort breaks ties on a validity key — valid slots always sort first.

    ``n_valid_in`` (per-device count; requires the caller to have set the
    trailing/inactive slots to ``key_pads``) restricts splitter sampling to
    valid slots and **excludes pad slots from the shuffle entirely** — with
    active-suffix discarding the all_to_all volume shrinks with the active
    fraction instead of staying O(m).
    """
    P, m = info.parts, info.part_size
    operands = tuple(operands)
    if key_pads is None:
        key_pads = tuple(pad_value(k.dtype) for k in operands[:num_keys])

    # 1. local sort (stable engines; caller's pad slots go last)
    ops = kernel_ops.local_sort(operands, num_keys, engine=local_sort,
                                key_bits=key_bits)
    keys_s = ops[:num_keys]
    m_valid = jnp.int32(m) if n_valid_in is None else n_valid_in.astype(jnp.int32)

    # 2. regular sampling over the valid prefix: P-1 local samples ->
    # all_gather -> global splitters.  (A device with few/no valid slots
    # contributes pad samples; that only skews splitters, and any resulting
    # imbalance is caught by the capacity overflow flag.)
    sample_pos = ((jnp.arange(1, P, dtype=jnp.int32)) * m_valid) // P
    local_samples = tuple(k[sample_pos] for k in keys_s)
    gathered = tuple(
        lax.all_gather(s, info.axis).reshape(-1) for s in local_samples
    )  # (P*(P-1),)
    gsorted = lax.sort(gathered, num_keys=num_keys)
    # P-1 splitters at regular positions
    spl_pos = (jnp.arange(1, P, dtype=jnp.int32) * (P * (P - 1))) // P
    splitters = tuple(g[spl_pos] for g in gsorted)

    # 3. bucket boundaries in the local sorted run (binary search per
    # splitter); pad slots sit past m_valid and are never sent
    bounds = jnp.minimum(_lex_searchsorted(keys_s, splitters), m_valid)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), bounds])
    ends = jnp.concatenate([bounds, m_valid[None]])
    counts = ends - starts                                  # (P,) per-dst

    cap = max(1, int(-(-capacity_factor * m // P)))
    overflow = jnp.any(counts > cap)

    # 4. build padded send buffers (P, cap) and shuffle
    slot = jnp.arange(cap, dtype=jnp.int32)
    take = starts[:, None] + slot[None, :]                  # (P, cap)
    valid_send = slot[None, :] < jnp.minimum(counts, cap)[:, None]
    take = jnp.clip(take, 0, m - 1)

    def exchange(buf):  # buf: (P, cap, ...) send blocks, block d -> device d
        return lax.all_to_all(
            buf.reshape(P * cap, *buf.shape[2:]), info.axis,
            split_axis=0, concat_axis=0, tiled=True,
        ).reshape(P, cap, *buf.shape[2:])

    def shuffle(x, pad):  # x: (m, ...) local sorted operand
        return exchange(jnp.where(valid_send, x[take], jnp.asarray(pad, x.dtype)))

    recv = tuple(
        shuffle(x, key_pads[i] if i < num_keys else 0)
        for i, x in enumerate(ops)
    )
    recv_valid = exchange(valid_send.astype(jnp.int32)).astype(bool)

    # 5. local sort of received slots; pads go to the end.  Validity is a
    # tie-break key after the real keys: a real key equal to its pad value
    # still sorts before the pad slots.
    flat = tuple(r.reshape(P * cap, *r.shape[2:]) for r in recv)
    vmask = recv_valid.reshape(P * cap)
    # force invalid slots to the pad on ALL keys so they sort last together
    flat = tuple(
        jnp.where(vmask, x, jnp.asarray(key_pads[i], x.dtype))
        if i < num_keys else x
        for i, x in enumerate(flat)
    )
    inv = (~vmask).astype(jnp.int32)
    tb_bits = None if key_bits is None else (*tuple(key_bits), 1)
    final = kernel_ops.local_sort(
        (*flat[:num_keys], inv, *flat[num_keys:]),
        num_keys + 1, engine=local_sort, key_bits=tb_bits,
    )
    final = (*final[:num_keys], *final[num_keys + 1:])
    n_valid = jnp.sum(vmask.astype(jnp.int32))
    return SampleSortResult(final, n_valid, lax.pmax(overflow, info.axis))


def scatter_to_index_samplesort(
    info: ShardInfo,
    gidx: jax.Array,
    values: tuple[jax.Array, ...],
    valid: jax.Array,
    capacity_factor: float = 2.0,
) -> tuple[tuple[jax.Array, ...], jax.Array]:
    """Route (gidx, *values) to the owner shard of each global index via a
    capacity-bounded all_to_all (owner = gidx // m).  Returns index-ordered
    local arrays + overflow flag.  Invalid slots (padding) are dropped."""
    P, m = info.parts, info.part_size
    slots = gidx.shape[0]
    dest = jnp.where(valid, gidx // m, P)  # P == "nowhere"

    # stable bucket slot: position among same-destination elements
    order = jnp.argsort(dest, stable=True)
    dest_s = dest[order]
    first = _lex_searchsorted((dest_s,), (dest_s,))
    slot_s = jnp.arange(slots, dtype=jnp.int32) - first
    cap = max(1, int(-(-capacity_factor * m // P)))
    overflow = jnp.any((dest_s < P) & (slot_s >= cap))

    def build(x):
        xs = x[order]
        buf = jnp.full((P, cap), -1, dtype=x.dtype)
        ok = (dest_s < P) & (slot_s < cap)
        row = jnp.where(ok, dest_s, P)  # row P is out of bounds -> dropped
        return buf.at[row, jnp.clip(slot_s, 0, cap - 1)].set(xs, mode="drop")

    def shuffle(buf):
        return lax.all_to_all(
            buf.reshape(P * cap), info.axis, split_axis=0, concat_axis=0,
            tiled=True,
        ).reshape(P, cap)

    gidx_r = shuffle(build(gidx)).reshape(-1)
    vals_r = tuple(shuffle(build(v)).reshape(-1) for v in values)
    ok = gidx_r >= 0
    local = jnp.where(ok, gidx_r % m, m)  # m is out of bounds -> dropped
    outs = tuple(
        jnp.zeros((m,), dtype=v.dtype).at[local].set(v, mode="drop")
        for v in vals_r
    )
    return outs, lax.pmax(overflow, info.axis)
