"""Distributed FM-index: sharded (bit-packed) BWT + rank queries via psum.

Scale story (DESIGN.md §2): for genome/corpus-scale indexes the BWT does not
fit one device, so it stays sharded over the mesh ``parts`` axis.  A rank
query Occ(c, p) decomposes over position ranges:

    Occ(c, p) = Σ_d  count of c in  (device d's range ∩ [0, p))

Each device answers from its local checkpoints (+ one in-block count), and a
single ``psum`` combines the partials — O(B) bytes of collective traffic per
backward-search step for a batch of B queries, independent of n.

The local rank path is the same engine as the single-device index: when the
alphabet packs (sigma <= 16) each shard stores the fused
[checkpoint | packed words] layout and dispatches through
``kernels/ops.rank_packed`` (Pallas popcount kernel on TPU, jnp fallback
elsewhere); larger alphabets fall back to ``ops.rank_unpacked``.

``dist_count`` (batched pattern counting) is the inference path lowered in
the multi-pod dry-run for the ``bwt_index`` config; ``dist_locate`` resolves
occurrence positions by LF-walking to a replicated SA sample, one psum-rank
per walk step.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map
from ..kernels import ops
from ..kernels.rank_select import pack_words, packed_bits
from .fm_index import PAD, build_sa_samples, sample_lookup

AXIS = "parts"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DistFMIndex:
    """Global arrays carry NamedShardings; static metadata rides as aux."""

    bwt: jax.Array          # int32[n]            sharded over parts
    occ_samples: jax.Array  # int32[nblocks, sigma] sharded (exclusive, per-shard)
    c_array: jax.Array      # int32[sigma]        replicated
    row: jax.Array          # int32 scalar        replicated
    fused: jax.Array | None        # int32[nblocks, sigma+W] sharded (packed)
    sa_marks: jax.Array | None     # int32[ceil(n/32)]  replicated
    sa_mark_ranks: jax.Array | None
    sa_vals: jax.Array | None      # raw int32, or packed when sa_val_bits > 0
    sample_rate: int
    sigma: int
    length: int
    parts: int
    bits: int               # packed field width (0 = unpacked layout)
    sa_sample_rate: int     # 0 = locate unavailable
    sa_val_bits: int = 0    # bits per packed SA value (0 = raw int32)

    def tree_flatten(self):
        return ((self.bwt, self.occ_samples, self.c_array, self.row,
                 self.fused, self.sa_marks, self.sa_mark_ranks, self.sa_vals),
                (self.sample_rate, self.sigma, self.length, self.parts,
                 self.bits, self.sa_sample_rate, self.sa_val_bits))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def _build_local(bwt_local: jax.Array, *, sigma: int, sample_rate: int,
                 bits: int):
    """Per-shard exclusive Occ checkpoints (+ fused packed rows) + C array."""
    m = bwt_local.shape[0]
    r = sample_rate
    nblocks = m // r
    onehot = (bwt_local[:, None] == jnp.arange(sigma)[None, :]).astype(jnp.int32)
    block_counts = onehot.reshape(nblocks, r, sigma).sum(axis=1)
    cum = jnp.cumsum(block_counts, axis=0)
    occ_local = jnp.concatenate([jnp.zeros((1, sigma), jnp.int32), cum[:-1]])
    totals = cum[-1]
    counts = lax.psum(totals, AXIS)
    c_array = jnp.cumsum(counts) - counts
    if bits:
        words = pack_words(bwt_local, bits).reshape(nblocks, -1)
        fused = jnp.concatenate([occ_local, words], axis=1)
    else:
        fused = jnp.zeros((1, 1), jnp.int32)  # placeholder, unused
    return occ_local, fused, c_array.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("sigma", "sample_rate", "bits",
                                             "mesh"))
def _build_jit(bwt, sigma, sample_rate, bits, mesh):
    fn = functools.partial(_build_local, sigma=sigma, sample_rate=sample_rate,
                           bits=bits)
    return shard_map(
        fn, mesh=mesh, in_specs=P(AXIS),
        out_specs=(P(AXIS), P(AXIS) if bits else P(), P()),
    )(bwt)


def build_dist_fm_index(
    bwt, row, mesh: Mesh, *, sigma: int, sample_rate: int = 64,
    sa=None, sa_sample_rate: int = 32, pack: bool | None = None,
    compress_sa: bool | None = None, sa_samples: tuple | None = None,
) -> DistFMIndex:
    """Shard a BWT over the mesh ``parts`` axis and build per-shard Occ
    checkpoints (+ fused packed rows when the alphabet fits).

    ``bwt`` int32[n] with n divisible by parts * sample_rate; ``sa`` /
    ``sa_sample_rate`` / ``compress_sa`` enable a replicated SA sample for
    ``dist_locate`` (as in ``fm_index.build_fm_index``); ``sa_samples``
    injects prebuilt (marks, ranks, vals, val_bits) on checkpoint restore.
    """
    n = bwt.shape[0]
    parts = mesh.shape[AXIS]
    if (n % parts) or ((n // parts) % sample_rate):
        raise ValueError(
            f"n={n} must be divisible by parts*sample_rate={parts}*{sample_rate}"
        )
    bits = 0 if pack is False else packed_bits(sigma, sample_rate)
    if pack and not bits:
        raise ValueError(
            f"cannot pack sigma={sigma} at sample_rate={sample_rate}"
        )
    bwt = jax.device_put(bwt, NamedSharding(mesh, P(AXIS)))
    occ_samples, fused, c_array = _build_jit(bwt, sigma, sample_rate, bits,
                                             mesh)
    if sa_samples is not None:
        sa_marks, sa_mark_ranks, sa_vals, sa_val_bits = sa_samples
    elif sa is not None:
        sa_marks, sa_mark_ranks, sa_vals, sa_val_bits = build_sa_samples(
            sa, sa_sample_rate, compress=compress_sa
        )
    else:
        sa_marks = sa_mark_ranks = sa_vals = None
        sa_sample_rate = sa_val_bits = 0
    return DistFMIndex(
        bwt, occ_samples, c_array, jnp.asarray(row, jnp.int32),
        fused if bits else None, sa_marks, sa_mark_ranks, sa_vals,
        sample_rate, sigma, n, parts, bits, sa_sample_rate, sa_val_bits,
    )


def _occ_partial(bwt_local, occ_local, fused_local, c, p, *, m, r, bits,
                 sigma):
    """count of character c in (my range ∩ [0, p)) — vectorised over queries,
    dispatched through kernels/ops on the local shard's layout.

    bwt_local int32[m]; c, p int32[B].  p_loc == m folds into the last block
    (cutoff r), so base + in-block covers exactly [0, m) with no tail case.
    """
    me = lax.axis_index(AXIS)
    p_loc = jnp.clip(p - me * m, 0, m)          # clip into my range
    block = jnp.minimum(p_loc // r, m // r - 1)
    cut = p_loc - block * r
    if bits:
        return ops.rank_packed(fused_local, block, c, cut,
                               bits=bits, sigma=sigma)
    base = occ_local[block, c]                   # (B,)
    inblock = ops.rank_unpacked(bwt_local.reshape(m // r, r), block, c, cut)
    return (base + inblock).astype(jnp.int32)


def _search_local(bwt_local, occ_local, fused_local, c_array, patterns,
                  *, m, r, n, bits, sigma):
    """shard_map body: batched backward search over replicated patterns."""

    def step(state, c):
        sp, ep = state
        in_alphabet = (c >= 1) & (c < sigma)
        valid = in_alphabet & (ep > sp)
        c_safe = jnp.where(in_alphabet, c, 0)
        occ_kw = dict(m=m, r=r, bits=bits, sigma=sigma)
        occ_sp = lax.psum(
            _occ_partial(bwt_local, occ_local, fused_local, c_safe, sp,
                         **occ_kw), AXIS)
        occ_ep = lax.psum(
            _occ_partial(bwt_local, occ_local, fused_local, c_safe, ep,
                         **occ_kw), AXIS)
        nsp = c_array[c_safe] + occ_sp
        nep = c_array[c_safe] + occ_ep
        sp = jnp.where(valid, nsp, sp)
        # out-of-alphabet symbols (not PAD) empty the interval permanently
        ep = jnp.where(valid, nep, jnp.where((c != PAD) & ~in_alphabet, sp, ep))
        return (sp, ep), None

    B = patterns.shape[0]
    init = (jnp.zeros(B, jnp.int32), jnp.full((B,), n, jnp.int32))
    # scan right-to-left over pattern positions (PADs on the right come first)
    (sp, ep), _ = lax.scan(step, init, patterns.T[::-1])
    return sp, ep


def _fused_operand(index: DistFMIndex):
    """The fused operand to ship into shard_map — a replicated dummy when
    the index is unpacked (the jits spec it P(AXIS) iff index.bits)."""
    return index.fused if index.bits else jnp.zeros((1, 1), jnp.int32)


@functools.partial(jax.jit, static_argnames=("index_static", "mesh"))
def _count_jit(index_arrays, patterns, index_static, mesh):
    sample_rate, sigma, n, parts, bits = index_static
    bwt, occ_samples, c_array, fused = index_arrays
    m = n // parts
    fn = functools.partial(
        _search_local, m=m, r=sample_rate, n=n, bits=bits, sigma=sigma
    )
    sp, ep = shard_map(
        fn, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS) if bits else P(), P(), P()),
        out_specs=(P(), P()),
    )(bwt, occ_samples, fused, c_array, patterns)
    return jnp.maximum(ep - sp, 0)


def dist_count(index: DistFMIndex, patterns, mesh: Mesh) -> jax.Array:
    """Batched exact-match counts over the sharded index.

    ``patterns``: int32[B, L], PAD-padded on the right, replicated.
    """
    arrays = (index.bwt, index.occ_samples, index.c_array,
              _fused_operand(index))
    static = (index.sample_rate, index.sigma, index.length, index.parts,
              index.bits)
    return _count_jit(arrays, jnp.asarray(patterns), static, mesh)


def _locate_local(bwt_local, occ_local, fused_local, c_array,
                  marks, mark_ranks, vals, patterns,
                  *, m, r, n, bits, sigma, s, k, val_bits):
    """shard_map body: backward search + LF-walk to the replicated SA sample.

    Every walk step costs one psum'd rank batch plus one psum'd BWT-symbol
    gather; positions/marks are replicated so all shards agree lane-by-lane.
    """
    sp, ep = _search_local(bwt_local, occ_local, fused_local, c_array,
                           patterns, m=m, r=r, n=n, bits=bits, sigma=sigma)
    B = sp.shape[0]
    me = lax.axis_index(AXIS)
    rows = sp[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    valid = (rows < ep[:, None]).reshape(-1)
    rows = jnp.where(valid, rows.reshape(-1), 0)
    occ_kw = dict(m=m, r=r, bits=bits, sigma=sigma)

    def bwt_at(rows):
        loc = rows - me * m
        inside = (loc >= 0) & (loc < m)
        sym = jnp.where(inside, bwt_local[jnp.clip(loc, 0, m - 1)], 0)
        return lax.psum(sym, AXIS)

    def body(_, st):
        rows, pos, steps, done = st
        marked, val = sample_lookup(marks, mark_ranks, vals, rows,
                                    val_bits=val_bits, val_scale=s)
        pos = jnp.where(marked & ~done, val + steps, pos)
        done = done | marked
        c = bwt_at(rows)
        nxt = c_array[c] + lax.psum(
            _occ_partial(bwt_local, occ_local, fused_local, c, rows, **occ_kw),
            AXIS)
        rows = jnp.where(done, rows, nxt)
        steps = steps + jnp.where(done, 0, 1)
        return rows, pos, steps, done

    zeros = jnp.zeros(B * k, jnp.int32)
    _, pos, _, _ = lax.fori_loop(0, s, body, (rows, zeros, zeros, ~valid))
    out = jnp.where(valid, pos, n).reshape(B, k)
    return jnp.sort(out, axis=1), jnp.minimum(jnp.maximum(ep - sp, 0), k)


@functools.partial(jax.jit, static_argnames=("index_static", "k", "mesh"))
def _locate_jit(index_arrays, patterns, index_static, k, mesh):
    sample_rate, sigma, n, parts, bits, s, val_bits = index_static
    bwt, occ_samples, c_array, fused, marks, mark_ranks, vals = index_arrays
    m = n // parts
    fn = functools.partial(
        _locate_local, m=m, r=sample_rate, n=n, bits=bits, sigma=sigma,
        s=s, k=k, val_bits=val_bits,
    )
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS) if bits else P(), P(),
                  P(), P(), P(), P()),
        out_specs=(P(), P()),
    )(bwt, occ_samples, fused, c_array, marks, mark_ranks, vals, patterns)


def dist_locate(index: DistFMIndex, patterns, k: int, mesh: Mesh):
    """First-k occurrence positions per pattern over the sharded index.

    Returns (positions int32[B, k] sorted ascending, n-filled; counts
    int32[B] clipped to k) — same contract as ``fm_index.locate``.
    """
    if index.sa_sample_rate == 0:
        raise ValueError("index built without sa= — locate unavailable")
    arrays = (index.bwt, index.occ_samples, index.c_array,
              _fused_operand(index),
              index.sa_marks, index.sa_mark_ranks, index.sa_vals)
    static = (index.sample_rate, index.sigma, index.length, index.parts,
              index.bits, index.sa_sample_rate, index.sa_val_bits)
    return _locate_jit(arrays, jnp.asarray(patterns), static, k, mesh)
