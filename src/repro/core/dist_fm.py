"""Distributed FM-index: sharded BWT + rank queries via masked psum.

Scale story (DESIGN.md §2): for genome/corpus-scale indexes the BWT does not
fit one device, so it stays sharded over the mesh ``parts`` axis.  A rank
query Occ(c, p) decomposes over position ranges:

    Occ(c, p) = Σ_d  count of c in  (device d's range ∩ [0, p))

Each device answers from its local checkpoints (+ one in-block scan), and a
single ``psum`` combines the partials — O(B) bytes of collective traffic per
backward-search step for a batch of B queries, independent of n.

``serve_step`` (batched pattern counting) is the inference path lowered in
the multi-pod dry-run for the ``bwt_index`` config.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .fm_index import PAD

AXIS = "parts"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DistFMIndex:
    """Global arrays carry NamedShardings; static metadata rides as aux."""

    bwt: jax.Array          # int32[n]            sharded over parts
    occ_samples: jax.Array  # int32[nblocks, sigma] sharded (exclusive, per-shard)
    c_array: jax.Array      # int32[sigma]        replicated
    row: jax.Array          # int32 scalar        replicated
    sample_rate: int
    sigma: int
    length: int
    parts: int

    def tree_flatten(self):
        return ((self.bwt, self.occ_samples, self.c_array, self.row),
                (self.sample_rate, self.sigma, self.length, self.parts))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def _build_local(bwt_local: jax.Array, *, sigma: int, sample_rate: int):
    """Per-shard exclusive Occ checkpoints + local totals."""
    m = bwt_local.shape[0]
    r = sample_rate
    nblocks = m // r
    onehot = (bwt_local[:, None] == jnp.arange(sigma)[None, :]).astype(jnp.int32)
    block_counts = onehot.reshape(nblocks, r, sigma).sum(axis=1)
    cum = jnp.cumsum(block_counts, axis=0)
    occ_local = jnp.concatenate([jnp.zeros((1, sigma), jnp.int32), cum[:-1]])
    totals = cum[-1]
    counts = lax.psum(totals, AXIS)
    c_array = jnp.cumsum(counts) - counts
    return occ_local, c_array.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("sigma", "sample_rate", "mesh"))
def _build_jit(bwt, sigma, sample_rate, mesh):
    fn = functools.partial(_build_local, sigma=sigma, sample_rate=sample_rate)
    return jax.shard_map(
        fn, mesh=mesh, in_specs=P(AXIS), out_specs=(P(AXIS), P())
    )(bwt)


def build_dist_fm_index(
    bwt, row, mesh: Mesh, *, sigma: int, sample_rate: int = 64
) -> DistFMIndex:
    n = bwt.shape[0]
    parts = mesh.shape[AXIS]
    if (n % parts) or ((n // parts) % sample_rate):
        raise ValueError(
            f"n={n} must be divisible by parts*sample_rate={parts}*{sample_rate}"
        )
    bwt = jax.device_put(bwt, NamedSharding(mesh, P(AXIS)))
    occ_samples, c_array = _build_jit(bwt, sigma, sample_rate, mesh)
    return DistFMIndex(
        bwt, occ_samples, c_array, jnp.asarray(row, jnp.int32),
        sample_rate, sigma, n, parts,
    )


def _occ_partial(bwt_local, occ_local, c, p, *, m, r):
    """count of character c in (my range ∩ [0, p)) — vectorised over queries.

    bwt_local int32[m], occ_local int32[m/r, sigma]; c, p int32[B].
    """
    me = lax.axis_index(AXIS)
    p_loc = jnp.clip(p - me * m, 0, m)          # clip into my range
    block = jnp.minimum(p_loc // r, m // r - 1)
    base = occ_local[block, c]                   # (B,)
    start = block * r
    window = bwt_local[start[:, None] + jnp.arange(r)[None, :]]   # (B, r)
    inblock = jnp.sum(
        (window == c[:, None]) & (start[:, None] + jnp.arange(r)[None, :] < p_loc[:, None]),
        axis=1,
    )
    # p_loc == m: block = m//r - 1, inblock counts the whole last block, so
    # base + inblock covers exactly [0, m) — no tail case needed.
    return (base + inblock).astype(jnp.int32)


def _search_local(bwt_local, occ_local, c_array, patterns, *, m, r, n):
    """shard_map body: batched backward search over replicated patterns."""

    def step(state, c):
        sp, ep = state
        sigma = c_array.shape[0]
        in_alphabet = (c >= 1) & (c < sigma)
        valid = in_alphabet & (ep > sp)
        c_safe = jnp.where(in_alphabet, c, 0)
        occ_sp = lax.psum(_occ_partial(bwt_local, occ_local, c_safe, sp, m=m, r=r), AXIS)
        occ_ep = lax.psum(_occ_partial(bwt_local, occ_local, c_safe, ep, m=m, r=r), AXIS)
        nsp = c_array[c_safe] + occ_sp
        nep = c_array[c_safe] + occ_ep
        sp = jnp.where(valid, nsp, sp)
        # out-of-alphabet symbols (not PAD) empty the interval permanently
        ep = jnp.where(valid, nep, jnp.where((c != PAD) & ~in_alphabet, sp, ep))
        return (sp, ep), None

    B = patterns.shape[0]
    init = (jnp.zeros(B, jnp.int32), jnp.full((B,), n, jnp.int32))
    # scan right-to-left over pattern positions (PADs on the right come first)
    (sp, ep), _ = lax.scan(step, init, patterns.T[::-1])
    return sp, ep


@functools.partial(jax.jit, static_argnames=("index_static", "mesh"))
def _count_jit(index_arrays, patterns, index_static, mesh):
    sample_rate, sigma, n, parts = index_static
    bwt, occ_samples, c_array, _row = index_arrays
    m = n // parts
    fn = functools.partial(
        _search_local, m=m, r=sample_rate, n=n
    )
    sp, ep = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(), P()),
        out_specs=(P(), P()),
    )(bwt, occ_samples, c_array, patterns)
    return jnp.maximum(ep - sp, 0)


def dist_count(index: DistFMIndex, patterns, mesh: Mesh) -> jax.Array:
    """Batched exact-match counts over the sharded index.

    ``patterns``: int32[B, L], PAD-padded on the right, replicated.
    """
    arrays, aux = index.tree_flatten()
    return _count_jit(arrays, jnp.asarray(patterns), aux, mesh)
