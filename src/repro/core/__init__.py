"""Core library: the paper's contribution — distributed BWT/FM indexing.

Public API:
    alphabet            token/alphabet conventions (sentinel = 0)
    suffix_array        single-device prefix doubling (reference)
    bwt                 BWT from SA + inverse (validation)
    fm_index            C array, sampled Occ, backward search
    competitor          Menon et al. MapReduce indexing (paper's baseline)
    dist_sort           distributed sort engines + scans (shard_map)
    dist_suffix_array   distributed prefix doubling + BWT
    dist_fm             sharded FM index, psum rank queries
    pipeline            end-to-end build_index() / SequenceIndex
"""

from .pipeline import SequenceIndex, build_index  # noqa: F401
