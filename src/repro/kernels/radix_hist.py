"""Pallas TPU kernel: per-block 8-bit digit histograms.

The counting pass of an LSD radix sort — the local-sort hot loop inside both
distributed sort engines (DESIGN.md §4).  Each grid step reads one key tile
from VMEM, extracts the digit at ``shift``, and writes that tile's 256-bin
histogram row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(keys_ref, out_ref, *, shift: int):
    keys = keys_ref[...].reshape(-1).astype(jnp.uint32)
    digits = (keys >> shift) & 0xFF
    onehot = digits[:, None] == jnp.arange(256, dtype=jnp.uint32)[None, :]
    out_ref[...] = onehot.sum(axis=0).astype(jnp.int32)[None, :]


def radix_hist_pallas(
    keys, shift: int, *, block: int = 1024, interpret: bool = False
):
    """keys int32[n] (n % block == 0) -> int32[n//block, 256] histograms."""
    n = keys.shape[0]
    if n % block:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    nblocks = n // block
    lanes = 128
    rows = block // lanes
    if block % lanes:
        raise ValueError(f"block={block} must be a multiple of {lanes}")
    x2d = keys.reshape(nblocks * rows, lanes)
    return pl.pallas_call(
        functools.partial(_kernel, shift=shift),
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((rows, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 256), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, 256), jnp.int32),
        interpret=interpret,
    )(x2d)
