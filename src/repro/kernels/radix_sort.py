"""LSD radix sort over packed uint32 key words — the local-sort engine of
the distributed BWT build (DESIGN.md §4).

Completes the orphaned ``radix_hist`` counting kernel into the full
hist -> exclusive-scan -> scatter pipeline, one 8-bit digit per pass:

  1. ``radix_hist_pallas``      per-block 256-bin digit histograms (VMEM)
  2. digit-major exclusive scan (tiny: nblocks x 256, plain jnp)
  3. ``radix_pos_pallas``       per-element destination = global bin base +
                                stable intra-block rank (onehot cumsum in
                                VMEM, no gathers — onehot-select only)
  4. apply                      one XLA scatter per operand

The scatter itself stays in XLA on purpose: Mosaic's block model cannot
express an arbitrary HBM scatter (an output block must be addressed by the
grid index map), while steps 1-3 — the compute-heavy part — stay in VMEM.

Keys are **field-limited**: only ``key_bits[w]`` low bits of word ``w`` are
significant (see ``core.keypack``), so a k-bit key costs ``ceil(k/8)``
passes instead of 4, and multi-word (64-bit logical) keys sort
least-significant word first.  Every pass is stable, hence so is the whole
sort — pad slots appended after real data stay behind equal real keys.

``radix_sort_jnp`` is the collective-free pure-jnp fallback used off-TPU
(same counting sort; the per-pass transient is an (n, 2^radix_bits) int32
cumsum, so auto mode narrows the digit with n to hold it near 64 MiB —
floored at 1-bit digits, where transients grow past the target for
n > 2^23); dispatch lives in ``kernels.ops.radix_sort``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .radix_hist import radix_hist_pallas


def _pos_kernel(keys_ref, base_ref, out_ref, *, shift: int, block: int):
    keys = keys_ref[...].reshape(-1).astype(jnp.uint32)
    digits = (keys >> shift) & 0xFF                       # (block,)
    bins = lax.broadcasted_iota(jnp.uint32, (block, 256), 1)
    onehot = digits[:, None] == bins                      # (block, 256)
    incl = jnp.cumsum(onehot.astype(jnp.int32), axis=0)   # stable intra rank
    base = base_ref[...].reshape(-1).astype(jnp.int32)    # (256,) bin bases
    pos = jnp.sum(jnp.where(onehot, base[None, :] + incl - 1, 0), axis=1)
    out_ref[...] = pos.astype(jnp.int32).reshape(out_ref.shape)


def radix_pos_pallas(keys, base, shift: int, *, block: int = 1024,
                     interpret: bool = False):
    """Destination position of every element for one 8-bit digit pass.

    keys uint32[n] (n % block == 0), base int32[n//block, 256] = global
    start of (block, digit) runs in digit-major order.
    """
    n = keys.shape[0]
    if n % block:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    nblocks = n // block
    lanes = 128
    rows = block // lanes
    if block % lanes:
        raise ValueError(f"block={block} must be a multiple of {lanes}")
    x2d = keys.reshape(nblocks * rows, lanes)
    out = pl.pallas_call(
        functools.partial(_pos_kernel, shift=shift, block=block),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
            pl.BlockSpec((1, 256), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks * rows, lanes), jnp.int32),
        interpret=interpret,
    )(x2d, base)
    return out.reshape(n)


def _digit_major_bases(hist):
    """(nblocks, 256) per-block histograms -> (nblocks, 256) global bin
    bases: exclusive scan in (digit, block) order."""
    nblocks, nbins = hist.shape
    flat = hist.T.reshape(-1)                   # digit-major
    starts = jnp.cumsum(flat) - flat
    return starts.reshape(nbins, nblocks).T.astype(jnp.int32)


def radix_sort_pallas(operands, num_keys: int, key_bits, *,
                      block: int = 1024, interpret: bool = False):
    """Stable LSD radix sort of uint32 key words + payload operands.

    ``operands[:num_keys]`` are uint32 key words, most-significant first
    (the ``lax.sort`` convention); ``key_bits[w]`` bounds the significant
    bits of word w.  n must be a multiple of ``block`` (ops pads).
    """
    arrs = list(operands)
    for w in range(num_keys - 1, -1, -1):
        for shift in range(0, key_bits[w], 8):
            word = arrs[w]
            hist = radix_hist_pallas(word, shift, block=block,
                                     interpret=interpret)
            base = _digit_major_bases(hist)
            pos = radix_pos_pallas(word, base, shift, block=block,
                                   interpret=interpret)
            arrs = [jnp.zeros_like(a).at[pos].set(a) for a in arrs]
    return tuple(arrs)


def radix_sort_jnp(operands, num_keys: int, key_bits, *,
                   radix_bits: int | None = None):
    """Pure-jnp stable LSD counting sort (the off-TPU fallback).

    The per-pass transient is an (n, 2^radix_bits) int32 cumsum; auto mode
    narrows the digit as n grows to keep it near 64 MiB (floor: 1-bit
    digits, so the bound is exceeded for n > 2^23 — more, cheaper passes
    beat an OOM).
    """
    n = operands[0].shape[0]
    if radix_bits is None:
        # n * 2^bits * 4 B <= ~2^26  =>  bits <= 24 - log2(n)
        radix_bits = max(1, min(8, 24 - max(1, n - 1).bit_length()))
    arrs = list(operands)
    for w in range(num_keys - 1, -1, -1):
        for shift in range(0, key_bits[w], radix_bits):
            nb = min(radix_bits, key_bits[w] - shift)
            nbins = 1 << nb
            word = arrs[w].astype(jnp.uint32)
            d = ((word >> shift) & (nbins - 1)).astype(jnp.int32)
            onehot = d[:, None] == jnp.arange(nbins, dtype=jnp.int32)[None, :]
            incl = jnp.cumsum(onehot.astype(jnp.int32), axis=0)
            totals = incl[-1]
            starts = jnp.cumsum(totals) - totals
            intra = jnp.take_along_axis(incl, d[:, None], axis=1)[:, 0] - 1
            pos = starts[d] + intra
            arrs = [jnp.zeros_like(a).at[pos].set(a) for a in arrs]
    return tuple(arrs)
