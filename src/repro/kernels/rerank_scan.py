"""Pallas TPU kernel: the paper's Re-rank step as a blocked carry scan.

Input: lexicographically sorted rank pairs (r1, r2).  Output: new ranks
(= global position of each equal-group's head) and the number of distinct
groups (the prefix-doubling termination counter).

The grid is sequential on TPU, so the cross-block carry — previous block's
last pair and its running head position — lives in an SMEM scratch that
persists across grid steps.  Inside a block the prefix-max is a
``lax.cummax`` over flagged global positions (VPU-friendly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r1_ref, r2_ref, ranks_ref, ngroups_ref, carry_ref, *, block: int):
    step = pl.program_id(0)
    r1 = r1_ref[...]
    r2 = r2_ref[...]

    @pl.when(step == 0)
    def _init():
        # carry = (prev_r1, prev_r2, running_head_max, num_groups)
        carry_ref[0] = r1[0] + 1  # != r1[0]: forces a head at position 0
        carry_ref[1] = r2[0] + 1
        carry_ref[2] = -1
        carry_ref[3] = 0

    prev1 = jnp.concatenate([carry_ref[0][None], r1[:-1]])
    prev2 = jnp.concatenate([carry_ref[1][None], r2[:-1]])
    flags = (r1 != prev1) | (r2 != prev2)

    gpos = step * block + jnp.arange(block, dtype=jnp.int32)
    heads = jnp.where(flags, gpos, -1)
    local = lax.cummax(heads)
    ranks = jnp.maximum(local, carry_ref[2])
    ranks_ref[...] = ranks.astype(jnp.int32)

    carry_ref[0] = r1[-1]
    carry_ref[1] = r2[-1]
    carry_ref[2] = ranks[-1]
    carry_ref[3] = carry_ref[3] + jnp.sum(flags.astype(jnp.int32))
    ngroups_ref[0] = carry_ref[3]


def rerank_scan_pallas(r1, r2, *, block: int = 512, interpret: bool = False):
    """(ranks int32[n], num_groups int32[1]); n % block == 0 required."""
    n = r1.shape[0]
    if n % block:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((4,), jnp.int32)],
        interpret=interpret,
    )(r1, r2)
