"""Jit'd public wrappers for the Pallas kernels.

Each wrapper auto-selects interpret mode off-TPU (this container is
CPU-only; TPU is the compile target), pads inputs to kernel granularity,
and exposes the same signature as the ``ref.py`` oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .char_histogram import char_histogram_pallas
from .radix_hist import radix_hist_pallas
from .radix_sort import radix_sort_jnp, radix_sort_pallas
from .rank_select import rank_packed_jnp, rank_packed_pallas, rank_select_pallas
from .rerank_scan import rerank_scan_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _rank_impl_default() -> str:
    """Build-time backend selection for the rank hot path: the real Pallas
    kernel on TPU, the pure-jnp popcount fallback elsewhere ("interpret" is
    opt-in for kernel parity tests — far too slow to serve from)."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


@functools.partial(jax.jit, static_argnames=("sigma", "block_rows", "interpret"))
def char_histogram(tokens, sigma: int, *, block_rows: int = 8,
                   interpret: bool | None = None):
    """Histogram of token values: int32[n] -> int32[sigma].

    Pallas kernel on TPU; ``interpret=None`` auto-selects interpret mode
    off-TPU.  Inputs pad to ``block_rows * 128`` lanes with the value
    ``sigma``, which lands out of range and is dropped by construction
    (padded lanes count into a scratch bin)."""
    interpret = _interpret_default() if interpret is None else interpret
    unit = block_rows * 128
    n = tokens.shape[0]
    pad = (-n) % unit
    if pad:
        # pad value sigma falls outside [0, sigma) -> contributes nothing
        tokens = jnp.pad(tokens, (0, pad), constant_values=sigma)
    return char_histogram_pallas(
        tokens, sigma, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def rerank_scan(r1, r2, *, block: int = 512, interpret: bool | None = None):
    """(ranks int32[n], num_groups int32 scalar) for sorted key pairs
    ``r1``/``r2`` int32[n]: rank = index of each pair's first occurrence.

    Pallas scan kernel (interpret mode auto-selected off-TPU); inputs pad to
    ``block`` with a strictly larger tail pair so padding forms its own
    trailing group, subtracted from ``num_groups`` before returning."""
    interpret = _interpret_default() if interpret is None else interpret
    n = r1.shape[0]
    pad = (-n) % block
    if pad:
        big = jnp.iinfo(jnp.int32).max
        r1 = jnp.pad(r1, (0, pad), constant_values=big)
        r2 = jnp.pad(r2, (0, pad), constant_values=big)
    ranks, ngroups = rerank_scan_pallas(r1, r2, block=block, interpret=interpret)
    if pad:
        ranks = ranks[:n]
        ngroups = ngroups - 1  # the padding group
    return ranks, ngroups[0]


def _sort_impl_default() -> str:
    """Local-sort backend for the build hot path: the Pallas radix pipeline
    on TPU, the pure-jnp counting sort elsewhere ("interpret" is opt-in for
    kernel parity tests)."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


COMPARE = "compare"
RADIX = "radix"


def resolve_sort_engine(engine: str) -> str:
    """"auto" -> the backend default: the radix engine on TPU, lax.sort
    off-TPU (the jnp counting-sort fallback loses ~3x to XLA's native sort
    on CPU)."""
    if engine == "auto":
        return RADIX if jax.default_backend() == "tpu" else COMPARE
    if engine not in (COMPARE, RADIX):
        raise ValueError(f"unknown local_sort engine {engine!r}")
    return engine


def local_sort(operands, num_keys: int, *, engine: str = COMPARE,
               key_bits=None):
    """Stable local sort of key operands + payloads by the chosen engine
    (the single dispatch used by both the single-device builder and the
    distributed sort engines).

    ``operands``: tuple of equal-length 1-D arrays, the first ``num_keys``
    of which are uint32/int32 sort keys (most-significant first).  Engine
    ``"compare"`` = ``lax.sort``; ``"radix"`` = the LSD radix pipeline
    below.  Both engines are stable, so they are interchangeable
    bit-for-bit."""
    operands = tuple(operands)
    if engine == RADIX:
        if key_bits is None:
            key_bits = (32,) * num_keys
        return radix_sort(operands, num_keys=num_keys,
                          key_bits=tuple(key_bits))
    return jax.lax.sort(operands, num_keys=num_keys, is_stable=True)


@functools.partial(
    jax.jit, static_argnames=("num_keys", "key_bits", "block", "impl")
)
def radix_sort(operands, *, num_keys: int, key_bits, block: int = 1024,
               impl: str | None = None):
    """Stable LSD radix sort of uint32 key words (MSW first) + payloads.

    ``key_bits[w]`` bounds the significant bits of key word ``w`` — pads
    (and every caller's pad slots, see ``core.keypack``) must be field-
    limited, because digits above ``key_bits`` are never examined.
    ``impl``: None -> backend default ("pallas" on TPU, "jnp" elsewhere);
    "interpret" runs the kernels in interpret mode for parity testing.
    """
    impl = _sort_impl_default() if impl is None else impl
    operands = tuple(operands)
    key_bits = tuple(key_bits)
    if impl == "jnp":
        return radix_sort_jnp(operands, num_keys, key_bits)
    n = operands[0].shape[0]
    pad = (-n) % block
    if pad:
        # pads go AFTER real data; per-pass stability keeps them there even
        # when a real key saturates its field (ties resolve to input order)
        operands = tuple(
            jnp.concatenate([
                a,
                jnp.full((pad,),
                         (1 << key_bits[i]) - 1 if i < num_keys else 0,
                         a.dtype),
            ])
            for i, a in enumerate(operands)
        )
    out = radix_sort_pallas(operands, num_keys, key_bits, block=block,
                            interpret=(impl == "interpret"))
    if pad:
        out = tuple(a[:n] for a in out)
    return out


@functools.partial(jax.jit, static_argnames=("shift", "block", "interpret"))
def radix_hist(keys, shift: int, *, block: int = 1024,
               interpret: bool | None = None):
    """Per-block 8-bit digit histograms: uint32[n] -> int32[n/block, 256]
    of counts of ``(keys >> shift) & 0xFF``.  ``block`` must divide n
    (callers tile); interpret mode auto-selected off-TPU."""
    interpret = _interpret_default() if interpret is None else interpret
    return radix_hist_pallas(keys, shift, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rank_select(bwt_blocks, block_idx, c, cutoff, *, interpret: bool | None = None):
    """In-block FM rank counts over unpacked symbols (scalar-prefetch
    gather kernel; interpret mode auto-selected off-TPU).

    ``bwt_blocks`` int32[n_blocks, r]; per query i the result counts
    occurrences of symbol ``c[i]`` in the first ``cutoff[i]`` positions of
    block ``block_idx[i]`` — all int32[B] -> int32[B]."""
    interpret = _interpret_default() if interpret is None else interpret
    return rank_select_pallas(
        bwt_blocks, block_idx, c, cutoff, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "sigma", "queries_per_step", "impl")
)
def rank_packed(fused, block_idx, c, cutoff, *, bits: int, sigma: int,
                queries_per_step: int = 8, impl: str | None = None):
    """Full rank queries (checkpoint base + in-block popcount) over the
    fused packed layout: Occ(c_i, block_idx_i * r + cutoff_i) for each query.

    ``fused`` int32[n_blocks, sigma + r*bits/32] rows of
    [Occ checkpoint | packed words]; ``block_idx``/``c``/``cutoff``
    int32[B] -> int32[B].  ``bits`` in {2, 4} is the packed field width.
    ``impl``: None -> backend default ("pallas" on TPU, "jnp" popcount
    fallback elsewhere); "interpret" runs the kernel in interpret mode for
    parity testing.  ``queries_per_step`` clamps to the next power of two
    >= B, so scalar walks (the BWT-merge interleave walk issues one- and
    two-query dispatches per step) don't pay for 8 grid lanes of work.
    """
    impl = _rank_impl_default() if impl is None else impl
    if impl == "jnp":
        return rank_packed_jnp(fused, block_idx, c, cutoff,
                               bits=bits, sigma=sigma)
    B = block_idx.shape[0]
    queries_per_step = min(
        queries_per_step, 1 << max(0, B - 1).bit_length()
    )
    pad = (-B) % queries_per_step
    if pad:
        z = jnp.zeros(pad, jnp.int32)
        block_idx, c, cutoff = (
            jnp.concatenate([a, z]) for a in (block_idx, c, cutoff)
        )
    out = rank_packed_pallas(
        fused, block_idx, c, cutoff, bits=bits, sigma=sigma,
        queries_per_step=queries_per_step, interpret=(impl == "interpret"),
    )
    return out[:B]


def rank_walkers(fused, blocks, occ, block_idx, c, cutoff, *, bits: int,
                 sigma: int):
    """Full Occ(c_i, block_idx_i * r + cutoff_i) on either block layout in
    ONE batched dispatch — the per-step rank call of the BWT-merge
    interleave walks (pairwise and k-way).

    Packed layouts (``bits`` > 0) pass ``fused`` (checkpoint base folds
    into the kernel); unpacked layouts pass ``blocks`` plus flat per-block
    Occ checkpoints ``occ`` int32[n_blocks, sigma].  ``block_idx`` may
    address a stacked multi-segment array (``fm_index.stack_rank_arrays``)
    with the lane base already folded in by the caller, so one dispatch
    ranks every walker of a k-way merge step against its own segment.
    """
    if bits:
        return rank_packed(fused, block_idx, c, cutoff,
                           bits=bits, sigma=sigma)
    return occ[block_idx, c] + rank_unpacked(blocks, block_idx, c, cutoff)


@functools.partial(jax.jit, static_argnames=("impl",))
def rank_unpacked(bwt_blocks, block_idx, c, cutoff, *, impl: str | None = None):
    """Batched in-block rank counts over unpacked int32 blocks (the sigma>16
    layout): same contract as ``rank_select`` (int32[B] queries ->
    int32[B] counts, NOT including the checkpoint base).  Dispatch:
    scalar-prefetch Pallas kernel on TPU, vectorised jnp gather elsewhere;
    "interpret" for parity testing."""
    impl = _rank_impl_default() if impl is None else impl
    if impl == "jnp":
        return ref.rank_select_ref(bwt_blocks, block_idx, c, cutoff)
    return rank_select_pallas(
        bwt_blocks, block_idx, c, cutoff, interpret=(impl == "interpret")
    )
