"""Jit'd public wrappers for the Pallas kernels.

Each wrapper auto-selects interpret mode off-TPU (this container is
CPU-only; TPU is the compile target), pads inputs to kernel granularity,
and exposes the same signature as the ``ref.py`` oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .char_histogram import char_histogram_pallas
from .radix_hist import radix_hist_pallas
from .rank_select import rank_packed_jnp, rank_packed_pallas, rank_select_pallas
from .rerank_scan import rerank_scan_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _rank_impl_default() -> str:
    """Build-time backend selection for the rank hot path: the real Pallas
    kernel on TPU, the pure-jnp popcount fallback elsewhere ("interpret" is
    opt-in for kernel parity tests — far too slow to serve from)."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


@functools.partial(jax.jit, static_argnames=("sigma", "block_rows", "interpret"))
def char_histogram(tokens, sigma: int, *, block_rows: int = 8,
                   interpret: bool | None = None):
    """Histogram of int32 tokens (pads with sigma, which lands out of range
    and is dropped by construction — padded lanes count into a scratch bin)."""
    interpret = _interpret_default() if interpret is None else interpret
    unit = block_rows * 128
    n = tokens.shape[0]
    pad = (-n) % unit
    if pad:
        # pad value sigma falls outside [0, sigma) -> contributes nothing
        tokens = jnp.pad(tokens, (0, pad), constant_values=sigma)
    return char_histogram_pallas(
        tokens, sigma, block_rows=block_rows, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def rerank_scan(r1, r2, *, block: int = 512, interpret: bool | None = None):
    """(ranks, num_groups) for sorted pairs; inputs padded with a strictly
    larger tail pair so padding forms its own trailing group."""
    interpret = _interpret_default() if interpret is None else interpret
    n = r1.shape[0]
    pad = (-n) % block
    if pad:
        big = jnp.iinfo(jnp.int32).max
        r1 = jnp.pad(r1, (0, pad), constant_values=big)
        r2 = jnp.pad(r2, (0, pad), constant_values=big)
    ranks, ngroups = rerank_scan_pallas(r1, r2, block=block, interpret=interpret)
    if pad:
        ranks = ranks[:n]
        ngroups = ngroups - 1  # the padding group
    return ranks, ngroups[0]


@functools.partial(jax.jit, static_argnames=("shift", "block", "interpret"))
def radix_hist(keys, shift: int, *, block: int = 1024,
               interpret: bool | None = None):
    """Per-block digit histograms; n must divide block (callers tile)."""
    interpret = _interpret_default() if interpret is None else interpret
    return radix_hist_pallas(keys, shift, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rank_select(bwt_blocks, block_idx, c, cutoff, *, interpret: bool | None = None):
    """In-block FM rank counts (scalar-prefetch gather kernel)."""
    interpret = _interpret_default() if interpret is None else interpret
    return rank_select_pallas(
        bwt_blocks, block_idx, c, cutoff, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("bits", "sigma", "queries_per_step", "impl")
)
def rank_packed(fused, block_idx, c, cutoff, *, bits: int, sigma: int,
                queries_per_step: int = 8, impl: str | None = None):
    """Full rank queries (checkpoint base + in-block popcount) over the
    fused packed layout.  ``impl``: None -> backend default ("pallas" on
    TPU, "jnp" elsewhere); "interpret" runs the kernel in interpret mode
    for parity testing.
    """
    impl = _rank_impl_default() if impl is None else impl
    if impl == "jnp":
        return rank_packed_jnp(fused, block_idx, c, cutoff,
                               bits=bits, sigma=sigma)
    B = block_idx.shape[0]
    pad = (-B) % queries_per_step
    if pad:
        z = jnp.zeros(pad, jnp.int32)
        block_idx, c, cutoff = (
            jnp.concatenate([a, z]) for a in (block_idx, c, cutoff)
        )
    out = rank_packed_pallas(
        fused, block_idx, c, cutoff, bits=bits, sigma=sigma,
        queries_per_step=queries_per_step, interpret=(impl == "interpret"),
    )
    return out[:B]


@functools.partial(jax.jit, static_argnames=("impl",))
def rank_unpacked(bwt_blocks, block_idx, c, cutoff, *, impl: str | None = None):
    """Batched in-block rank counts over unpacked int32 blocks (the sigma>16
    layout): scalar-prefetch kernel on TPU, vectorised gather elsewhere."""
    impl = _rank_impl_default() if impl is None else impl
    if impl == "jnp":
        return ref.rank_select_ref(bwt_blocks, block_idx, c, cutoff)
    return rank_select_pallas(
        bwt_blocks, block_idx, c, cutoff, interpret=(impl == "interpret")
    )
