"""Pallas TPU kernel: FM-index in-block rank queries via scalar prefetch.

The serving hot spot: each backward-search step needs Occ(c, p) for a batch
of data-dependent positions.  The checkpointed base is a cheap gather; the
in-block count needs the right BWT tile per query.  On TPU this is the
canonical scalar-prefetch pattern: the block indices arrive as prefetched
scalars, and the BlockSpec index_map selects which HBM tile to DMA into
VMEM for each grid step — a data-dependent gather expressed structurally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(block_idx_ref, c_ref, cutoff_ref, bwt_ref, out_ref):
    q = pl.program_id(0)
    c = c_ref[q]
    cutoff = cutoff_ref[q]
    blk = bwt_ref[0, :]
    pos = jnp.arange(blk.shape[0], dtype=jnp.int32)
    out_ref[0] = jnp.sum((blk == c) & (pos < cutoff)).astype(jnp.int32)


def rank_select_pallas(bwt_blocks, block_idx, c, cutoff, *, interpret=False):
    """In-block counts for FM rank queries.

    bwt_blocks int32[nblocks, r]; block_idx/c/cutoff int32[B].
    Returns int32[B]: count of c among the first ``cutoff`` entries of the
    selected block, one query per grid step.
    """
    B = block_idx.shape[0]
    r = bwt_blocks.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, r), lambda q, bidx, c, cut: (bidx[q], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda q, bidx, c, cut: (q,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(block_idx, c, cutoff, bwt_blocks)
