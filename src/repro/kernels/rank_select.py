"""Pallas TPU kernels for FM-index rank queries over a bit-packed BWT.

Layout (the "succinct" direction of Sirén's terabase-scale BWT work): the
BWT is planed into 2-bit (sigma <= 4) or 4-bit (sigma <= 16) fields packed
LSB-first into int32 words, and each checkpoint block is stored as one
*fused* row

    fused[b] = [ Occ checkpoint (sigma int32) | packed words (r/fpw int32) ]

so a single row fetch (one cache line / one DMA) yields both the rank base
and the block payload — the interleaved-checkpoint struct of classic
cache-aware FM indexes.

``rank_packed_pallas`` is the fused kernel: a grid step answers
``queries_per_step`` rank queries against the whole fused array resident in
VMEM (bit-packing shrinks it 8-16x vs int32 symbols, so corpus-scale shards
fit), counting matches popcount-style over packed words instead of scanning
symbols.  ``rank_packed_jnp`` is the same math as a pure-jnp fallback for
hosts without a TPU (selected at build/dispatch time in ``ops.py``).

``rank_select_pallas`` is the legacy one-query-per-grid-step scalar-prefetch
kernel over *unpacked* int32 blocks; it remains the fallback layout for
alphabets too large to pack (sigma > 16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# LSB of every 2-bit / 4-bit field — replicating a symbol across fields is
# one multiply; a field equals the symbol iff its XOR-difference is zero.
_REP = {2: 0x55555555, 4: 0x11111111}


def packed_bits(sigma: int, sample_rate: int) -> int:
    """Field width for (sigma, block length r): 2, 4, or 0 (unpackable)."""
    for bits in (2, 4):
        if sigma <= (1 << bits) and sample_rate % (32 // bits) == 0:
            return bits
    return 0


def pack_words(symbols: jax.Array, bits: int) -> jax.Array:
    """int32[k*fpw] symbols in [0, 2^bits) -> int32[k] packed words.

    Negative entries (PAD tails) pack as 0; rank queries never reach them
    because in-block cutoffs are bounded by the true text length.
    """
    fpw = 32 // bits
    v = jnp.maximum(symbols, 0).astype(jnp.uint32).reshape(-1, fpw)
    shifts = jnp.arange(fpw, dtype=jnp.uint32) * jnp.uint32(bits)
    words = jnp.sum(v << shifts[None, :], axis=1, dtype=jnp.uint32)
    return lax.bitcast_convert_type(words, jnp.int32)


def _eq_fields(x: jax.Array, bits: int) -> jax.Array:
    """Per-field zero test on XOR-ed packed words: LSB of each field is 1
    iff the whole field is 0 (i.e. the symbols matched)."""
    rep = jnp.uint32(_REP[bits])
    t = x | (x >> 1)
    if bits == 4:
        t = t | (t >> 2)
    return (t & rep) ^ rep


def _cutoff_mask(word_iota, cutoff, bits: int):
    """uint32 select mask keeping only the first ``cutoff`` fields of a
    block laid out over consecutive words (cutoff in [0, r])."""
    fpw = 32 // bits
    full = cutoff // fpw
    rem = (cutoff - full * fpw).astype(jnp.uint32)
    partial = (jnp.uint32(1) << (jnp.uint32(bits) * rem)) - jnp.uint32(1)
    return jnp.where(
        word_iota < full,
        jnp.uint32(0xFFFFFFFF),
        jnp.where(word_iota == full, partial, jnp.uint32(0)),
    )


def rank_packed_jnp(fused, block_idx, c, cutoff, *, bits: int, sigma: int):
    """Pure-jnp popcount rank over the fused layout (CPU fallback).

    fused int32[nb, sigma + W]; block_idx/c/cutoff int32[B].
    Returns int32[B]: Occ checkpoint + count of c in the first ``cutoff``
    symbols of the selected block.
    """
    rows = fused[block_idx]                                  # (B, sigma+W)
    base = jnp.take_along_axis(rows, c[:, None], axis=1)[:, 0]
    w = lax.bitcast_convert_type(rows[:, sigma:], jnp.uint32)  # (B, W)
    rep = jnp.uint32(_REP[bits])
    eq = _eq_fields(w ^ (c.astype(jnp.uint32) * rep)[:, None], bits)
    wi = jnp.arange(w.shape[1], dtype=jnp.int32)[None, :]
    sel = _cutoff_mask(wi, cutoff[:, None], bits)
    cnt = jnp.sum(lax.population_count(eq & sel), axis=1)
    return (base + cnt.astype(jnp.int32)).astype(jnp.int32)


def _packed_kernel(bidx_ref, c_ref, cut_ref, fused_ref, out_ref,
                   *, bits: int, sigma: int, queries_per_step: int):
    i = pl.program_id(0)
    wid = fused_ref.shape[1]
    W = wid - sigma
    rep = jnp.uint32(_REP[bits])

    def body(q, acc):
        g = i * queries_per_step + q
        blk = bidx_ref[g]
        c = c_ref[g]
        cut = cut_ref[g]
        row = fused_ref[pl.ds(blk, 1), :]                    # (1, sigma+W)
        base = lax.dynamic_slice(row, (0, c), (1, 1))[0, 0]
        w = lax.bitcast_convert_type(
            lax.slice(row, (0, sigma), (1, wid)), jnp.uint32
        )                                                    # (1, W)
        eq = _eq_fields(w ^ c.astype(jnp.uint32) * rep, bits)
        wi = lax.broadcasted_iota(jnp.int32, (1, W), 1)
        sel = _cutoff_mask(wi, cut, bits)
        cnt = jnp.sum(lax.population_count(eq & sel)).astype(jnp.int32)
        return acc.at[q].set(base + cnt)

    out_ref[:] = lax.fori_loop(
        0, queries_per_step, body,
        jnp.zeros((queries_per_step,), jnp.int32),
    )


def rank_packed_pallas(fused, block_idx, c, cutoff, *, bits: int, sigma: int,
                       queries_per_step: int = 8, interpret: bool = False):
    """Fused multi-query rank kernel over the packed layout.

    The whole fused array lives in VMEM (packing makes it small); every grid
    step answers ``queries_per_step`` queries, each gathering one fused row
    (checkpoint base + packed words in a single access) and counting matches
    via XOR + popcount.  B must be a multiple of queries_per_step (ops.py
    pads).
    """
    B = block_idx.shape[0]
    Q = queries_per_step
    assert B % Q == 0, (B, Q)
    nb, wid = fused.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B // Q,),
        in_specs=[pl.BlockSpec((nb, wid), lambda i, b, c, t: (0, 0))],
        out_specs=pl.BlockSpec((Q,), lambda i, b, c, t: (i,)),
    )
    kernel = functools.partial(
        _packed_kernel, bits=bits, sigma=sigma, queries_per_step=Q
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(block_idx, c, cutoff, fused)


# ---------------------------------------------------------------------------
# legacy unpacked path (sigma > 16): one query per grid step, scalar-prefetch
# DMA of the selected int32 block.
# ---------------------------------------------------------------------------


def _kernel(block_idx_ref, c_ref, cutoff_ref, bwt_ref, out_ref):
    q = pl.program_id(0)
    c = c_ref[q]
    cutoff = cutoff_ref[q]
    blk = bwt_ref[0, :]
    pos = jnp.arange(blk.shape[0], dtype=jnp.int32)
    out_ref[0] = jnp.sum((blk == c) & (pos < cutoff)).astype(jnp.int32)


def rank_select_pallas(bwt_blocks, block_idx, c, cutoff, *, interpret=False):
    """In-block counts for FM rank queries over unpacked int32 blocks.

    bwt_blocks int32[nblocks, r]; block_idx/c/cutoff int32[B].
    Returns int32[B]: count of c among the first ``cutoff`` entries of the
    selected block, one query per grid step.
    """
    B = block_idx.shape[0]
    r = bwt_blocks.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, r), lambda q, bidx, c, cut: (bidx[q], 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda q, bidx, c, cut: (q,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B,), jnp.int32),
        interpret=interpret,
    )(block_idx, c, cutoff, bwt_blocks)
