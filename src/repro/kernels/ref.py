"""Pure-jnp oracles for every Pallas kernel (the contract each kernel's
output is asserted against, on full shape/dtype sweeps — tests/test_kernels).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def char_histogram_ref(tokens: jax.Array, sigma: int) -> jax.Array:
    """Histogram of token values: int32[sigma]."""
    return jnp.bincount(tokens.reshape(-1), length=sigma).astype(jnp.int32)


def rerank_scan_ref(r1: jax.Array, r2: jax.Array):
    """Paper's Re-rank on a sorted pair sequence.

    Returns (ranks int32[n], num_groups int32): rank = position of the head
    of each equal-group; num_groups counts distinct pairs.
    """
    n = r1.shape[0]
    neq = (r1[1:] != r1[:-1]) | (r2[1:] != r2[:-1])
    flags = jnp.concatenate([jnp.ones((1,), bool), neq])
    heads = jnp.where(flags, jnp.arange(n, dtype=jnp.int32), -1)
    ranks = lax.associative_scan(jnp.maximum, heads)
    return ranks.astype(jnp.int32), jnp.sum(flags).astype(jnp.int32)


def radix_hist_ref(keys: jax.Array, shift: int, block: int) -> jax.Array:
    """Per-block 8-bit digit histograms: int32[n//block, 256]."""
    digits = (keys.astype(jnp.uint32) >> shift) & 0xFF
    digits = digits.reshape(-1, block)
    onehot = digits[..., None] == jnp.arange(256, dtype=jnp.uint32)
    return onehot.sum(axis=1).astype(jnp.int32)


def radix_sort_ref(operands, num_keys: int):
    """Stable-sort oracle for the radix pipeline (XLA's stable sort)."""
    return lax.sort(tuple(operands), num_keys=num_keys, is_stable=True)


def rank_select_ref(
    bwt_blocks: jax.Array, block_idx: jax.Array, c: jax.Array, cutoff: jax.Array
) -> jax.Array:
    """In-block occurrence counts for FM rank queries.

    bwt_blocks int32[nblocks, r]; for query q: count of ``c[q]`` among the
    first ``cutoff[q]`` positions of block ``block_idx[q]``.
    """
    r = bwt_blocks.shape[1]
    blocks = bwt_blocks[block_idx]                      # (B, r)
    pos = jnp.arange(r, dtype=jnp.int32)[None, :]
    return jnp.sum(
        (blocks == c[:, None]) & (pos < cutoff[:, None]), axis=1
    ).astype(jnp.int32)


def unpack_words(words: jax.Array, bits: int) -> jax.Array:
    """int32[..., W] packed words -> int32[..., W * (32//bits)] symbols
    (LSB-first field order — inverse of rank_select.pack_words)."""
    fpw = 32 // bits
    w = lax.bitcast_convert_type(words, jnp.uint32)[..., None]
    shifts = jnp.arange(fpw, dtype=jnp.uint32) * jnp.uint32(bits)
    fields = (w >> shifts) & jnp.uint32((1 << bits) - 1)
    return fields.reshape(*words.shape[:-1], -1).astype(jnp.int32)


def rank_packed_ref(fused, block_idx, c, cutoff, *, bits: int, sigma: int):
    """Oracle for the packed fused layout: unpack the selected block back to
    plain symbols and count the slow, obvious way (checkpoint base + scan).

    fused int32[nb, sigma + W]: per-block [Occ checkpoint | packed words].
    Deliberately shares no bit-twiddling with the production popcount path.
    """
    rows = fused[block_idx]                             # (B, sigma+W)
    base = jnp.take_along_axis(rows, c[:, None], axis=1)[:, 0]
    syms = unpack_words(rows[:, sigma:], bits)          # (B, r)
    pos = jnp.arange(syms.shape[1], dtype=jnp.int32)[None, :]
    inblock = jnp.sum(
        (syms == c[:, None]) & (pos < cutoff[:, None]), axis=1
    )
    return (base + inblock).astype(jnp.int32)
