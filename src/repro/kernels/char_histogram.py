"""Pallas TPU kernel: blocked character histogram (paper's Init map/reduce).

Counts token occurrences over VMEM tiles of shape (rows, 128) and
accumulates into a single int32[sigma] output that every grid step maps to
(revisited blocks persist on TPU, so the accumulation is race-free on the
sequential grid).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, out_ref, *, sigma: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].reshape(-1)                                # (rows*128,)
    onehot = (x[:, None] == jnp.arange(sigma, dtype=x.dtype)[None, :])
    out_ref[...] += onehot.sum(axis=0).astype(jnp.int32)


def char_histogram_pallas(
    tokens, sigma: int, *, block_rows: int = 8, interpret: bool = False
):
    """tokens int32[n] with n % (block_rows*128) == 0 -> int32[sigma]."""
    n = tokens.shape[0]
    lanes = 128
    rows = n // lanes
    if n % (block_rows * lanes):
        raise ValueError(f"n={n} must be a multiple of {block_rows * lanes}")
    x2d = tokens.reshape(rows, lanes)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_kernel, sigma=sigma),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((sigma,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((sigma,), jnp.int32),
        interpret=interpret,
    )(x2d)
