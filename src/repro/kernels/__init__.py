"""Pallas TPU kernels for the indexing hot spots (DESIGN.md §2).

Each kernel: <name>.py (pl.pallas_call + BlockSpec), a jit wrapper in
ops.py, and a pure-jnp oracle in ref.py; tests sweep shapes/dtypes and
assert exact agreement in interpret mode.
"""

from .ops import (  # noqa: F401
    char_histogram,
    radix_hist,
    radix_sort,
    rank_packed,
    rank_select,
    rank_unpacked,
    rerank_scan,
)
