"""repro: distributed BWT sequence indexing on TPU pods (JAX + Pallas),
integrated with a multi-pod LM training/serving framework.

Reproduction of Randazzo & Rombo 2020 — see README.md / DESIGN.md.
"""

__version__ = "1.0.0"
