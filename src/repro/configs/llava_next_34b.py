"""llava-next-34b [vlm] — transformer BACKBONE only; the anyres-tiling
vision frontend is a stub (input_specs provides precomputed patch
embeddings, per assignment).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    vocab_size=64000,
    attention="gqa",
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    mlp="swiglu",
    frontend="patch",
    rope_theta=5000000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2,
        d_model=64,
        vocab_size=512,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
    )
