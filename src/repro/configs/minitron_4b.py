"""minitron-4b [dense] — pruned Nemotron-4 (squared-ReLU MLP, GQA).
[arXiv:2407.14679; hf]  32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    vocab_size=256000,
    attention="gqa",
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    mlp="relu2",
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2,
        d_model=64,
        vocab_size=512,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
    )
