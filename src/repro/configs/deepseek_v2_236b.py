"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff(expert)=1536 vocab=102400.
Layer 0 uses a dense FFN (d_ff 12288) per the HF config.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    vocab_size=102400,
    attention="mla",
    num_heads=128,
    head_dim=128,             # qk_nope dim (per-head)
    d_ff=12288,               # dense-FFN width (prefix layer)
    mlp="swiglu",
    num_experts=160,
    top_k=6,
    num_shared_experts=2,
    moe_d_ff=1536,
    first_dense_layers=1,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        vocab_size=512,
        num_heads=4,
        head_dim=16,
        d_ff=128,
        num_experts=8,
        top_k=2,
        num_shared_experts=1,
        moe_d_ff=32,
        first_dense_layers=1,
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
    )
