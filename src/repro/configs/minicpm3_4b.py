"""minicpm3-4b [dense] — dense transformer with MLA attention.
[hf:openbmb/MiniCPM3-4B; hf]  62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA dims from the HF config family: q_lora 768, kv_lora 256, qk_nope 64,
qk_rope 32, v_head 64 (mu-param residual scaling omitted — init detail,
DESIGN.md §5).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    vocab_size=73448,
    attention="mla",
    num_heads=40,
    head_dim=64,
    d_ff=6400,
    mlp="swiglu",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=3,
        d_model=64,
        vocab_size=512,
        num_heads=4,
        head_dim=16,
        d_ff=128,
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
    )
