"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.

Assignment-line config: every layer MoE (128e top-1, expert d_ff 8192) with
one shared expert.  HF Maverick interleaves dense layers; the assignment
line wins (DESIGN.md §5, [unverified] tier).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    vocab_size=202048,
    attention="gqa",
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    mlp="swiglu",
    num_experts=128,
    top_k=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    rope_theta=500000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2,
        d_model=64,
        vocab_size=512,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=64,
        num_experts=8,
        top_k=1,
        num_shared_experts=1,
        moe_d_ff=64,
    )
