"""The paper's own workload as a first-class config: distributed BWT index
construction + FM-index query serving.

``train_step`` analogue = one prefix-doubling build over an n-token string;
``serve_step`` = batched FM backward-search counting.  The dry-run lowers
both on the production mesh (string sharded over every chip).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class BWTIndexConfig:
    name: str = "bwt_index"
    family: str = "index"
    n: int = 1 << 28              # 256 Mi tokens (PROTEINS/DNA-scale, §3)
    sigma: int = 257              # byte alphabet + sentinel
    engine: str = "samplesort"    # paper-faithful range shuffle by default
    capacity_factor: float = 2.0
    sample_rate: int = 64         # FM Occ checkpoint spacing
    query_batch: int = 1024
    query_len: int = 32
    rounds: int | None = None     # None -> ceil(log2 n)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


CONFIG = BWTIndexConfig()


def reduced() -> BWTIndexConfig:
    return CONFIG.replace(n=1 << 12, query_batch=8, query_len=8, rounds=None)
