"""The paper's own workload as a first-class config: distributed BWT index
construction + FM-index query serving.

``train_step`` analogue = one prefix-doubling build over an n-token string;
``serve_step`` = batched FM backward-search counting.  The dry-run lowers
both on the production mesh (string sharded over every chip).
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class BWTIndexConfig:
    name: str = "bwt_index"
    family: str = "index"
    n: int = 1 << 28              # 256 Mi tokens (PROTEINS/DNA-scale, §3)
    sigma: int = 257              # byte alphabet + sentinel
    engine: str = "samplesort"    # paper-faithful range shuffle by default
    capacity_factor: float = 2.0
    # build-engine knobs (PR 2): fused keys are always on; these gate the
    # packed q-gram init, active-suffix discarding, and the local sort
    qgram: bool = True            # rank by q packed chars, start at h=q
    qgram_words: int = 2          # uint32 words per init key (64-bit logical)
    discard: bool = True          # drop unique-rank suffixes from the loop
    local_sort: str = "auto"      # "compare" | "radix" | "auto" (radix on TPU)
    sample_rate: int = 64         # FM Occ checkpoint spacing
    query_batch: int = 1024
    query_len: int = 32
    rounds: int | None = None     # None -> ceil(log2 n)

    # query engine: pack/sa_sample_rate feed pipeline.build_index, the
    # serve_* knobs feed serving.engine.FMQueryServer.from_config
    pack: bool | None = None      # None: bit-pack whenever sigma <= 16
    sa_sample_rate: int = 32      # SA sampling stride for locate() (0 = off)
    compress_sa: bool | None = None  # None: bit-pack SA values when smaller
    locate_k: int = 16            # occurrences returned per locate query
    serve_length_buckets: tuple[int, ...] = (8, 16, 32, 64)
    serve_max_batch: int = 1024   # micro-batch cap per jit bucket

    # async frontend (serving/frontend.py): admission-controlled queue in
    # front of FMQueryServer.flush — overload sheds (Rejected) instead of
    # growing without bound; per-bucket p50/p99 tracked against the SLOs
    serve_queue_depth: int = 8192     # admission bound; beyond this -> shed
    serve_max_wait_ms: float = 2.0    # flush coalescing window
    serve_slo_p99_ms: float = 50.0    # per-bucket p99 target, count queries
    serve_slo_p99_ms_locate: float = 200.0  # same, locate (LF-walk heavy)
    serve_parallel_segments: bool | None = None  # SegmentedIndex fan-out
                                      # (None = auto: stacked when >= 2)
    # growth-op fault policy (frontend appends/compactions): transient
    # failures retry with capped exponential backoff; a compaction that
    # exhausts its retries is quarantined (pre-compact generation serves)
    serve_growth_retries: int = 3
    serve_growth_backoff_ms: float = 5.0

    # index lifecycle: ckpt_dir/ckpt_keep default launch.serve's --ckpt-dir/
    # --ckpt-keep flags (core/index_io.py checkpoints restore onto any mesh
    # shape); compress_sa + segment_min_tokens feed pipeline.build_index and
    # SegmentedIndex.from_config (segments smaller than the threshold merge
    # on compact())
    ckpt_dir: str | None = None   # None = index dies with the process
    ckpt_keep: int = 3            # retained checkpoint steps
    segment_min_tokens: int = 1 << 22  # compact() threshold for small segments
    # background compaction policy (SegmentedIndex.maybe_compact, run by the
    # serving path between flushes): "merge" = cost-model auto-pick per run
    # between the pairwise fold, the k-way interleave walk, and the rebuild
    # (core/bwt_merge; rebuild remains the fallback for ineligible runs);
    # "pairwise"/"kway" force one merge flavor; "rebuild" = always re-sort
    # from raw tokens.  The trigger is cost-based: a run of small adjacent
    # segments compacts when the cheapest merge estimate costs at most
    # trigger_cost_ratio of the rebuild estimate, when re-sorting the run
    # costs no more than one merge's fixed dispatch (deferring a tiny run
    # can never pay), or when the run reaches compact_max_small segments
    # (fan-out backstop).  compact_trigger_ratio is the legacy fixed-ratio
    # knob, kept for catalog compatibility only.
    compact_strategy: str = "merge"
    compact_trigger_ratio: float = 0.5
    compact_max_small: int = 8
    compact_trigger_cost_ratio: float = 0.75
    # cost-model constants, calibrated from compact_bench --smoke on the
    # CPU backend: one sequential pairwise walk step, one k-way walk step
    # (ranks every walker lane, ~2x a pairwise step), one token of
    # splice/resample work, one token*log2(n) of sort work, fixed
    # per-merge-op overhead (jit entry + host splice — the term that sinks
    # the pairwise fold on wide runs)
    compact_cost_walk_ns: float = 800.0
    compact_cost_kway_walk_ns: float = 1600.0
    compact_cost_token_ns: float = 50.0
    compact_cost_sort_ns: float = 55.0
    compact_cost_merge_us: float = 10000.0

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


CONFIG = BWTIndexConfig()


def reduced() -> BWTIndexConfig:
    return CONFIG.replace(n=1 << 12, query_batch=8, query_len=8, rounds=None,
                          sa_sample_rate=8, locate_k=4,
                          serve_length_buckets=(4, 8), serve_max_batch=8,
                          serve_queue_depth=64, serve_max_wait_ms=1.0)
