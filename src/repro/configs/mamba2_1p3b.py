"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]  48L d_model=2048 d_inner=4096 (expand 2),
headdim=64, ssm_state=128, vocab=50280.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    d_inner=4096,
    ssm_headdim=64,
    ssm_groups=1,
    conv_width=4,
    ssd_chunk=256,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=4,
        d_model=64,
        vocab_size=512,
        ssm_state=16,
        d_inner=128,
        ssm_headdim=32,
        ssd_chunk=8,
    )
