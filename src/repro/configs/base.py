"""Architecture configuration schema + registry.

One ``ArchConfig`` instance per assigned architecture lives in
``configs/<id>.py``; ``reduced()`` derives the CPU smoke-test config of the
same family (small widths, few layers/experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

LayerKind = Literal["attn", "local_attn", "rglru", "ssm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int

    # attention
    attention: str = "gqa"          # gqa | mla | none
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10000.0

    # MLP
    d_ff: int = 0
    mlp: str = "swiglu"             # swiglu | relu2 | gelu

    # MoE (num_experts == 0 -> dense FFN everywhere)
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0               # routed expert hidden size
    first_dense_layers: int = 0     # leading layers with dense FFN
    capacity_factor: float = 1.25

    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    d_inner: int = 0
    ssm_headdim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 64

    # hybrid (recurrentgemma): repeating layer pattern
    layer_pattern: tuple[str, ...] = ()
    window: int = 0                 # local attention window
    lru_width: int = 0

    # modality frontend stub: none | patch | frame
    frontend: str = "none"

    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # sub-quadratic? (drives the long_500k skip rule)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def num_heads_or_1(self) -> int:
        return max(1, self.num_heads)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


ARCH_IDS = [
    "recurrentgemma_2b",
    "deepseek_v2_236b",
    "llama4_maverick_400b_a17b",
    "mamba2_1p3b",
    "minitron_4b",
    "minicpm3_4b",
    "qwen2p5_3b",
    "nemotron_4_15b",
    "llava_next_34b",
    "musicgen_medium",
    "bwt_index",                    # the paper's own workload as a config
]


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.CONFIG


def get_reduced_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.reduced()
