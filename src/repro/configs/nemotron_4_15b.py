"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP.
[arXiv:2402.16819; unverified]  32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    vocab_size=256000,
    attention="gqa",
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    mlp="relu2",
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2,
        d_model=64,
        vocab_size=512,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
    )
