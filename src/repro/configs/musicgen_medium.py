"""musicgen-medium [audio] — decoder-only over EnCodec tokens; the EnCodec
frame frontend is a stub (input_specs provides precomputed frame embeddings,
per assignment).  Codebook delay-pattern interleaving is out of scope
(single-stream decoding, DESIGN.md §5).
[arXiv:2306.05284; hf]  48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    vocab_size=2048,
    attention="gqa",
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    mlp="gelu",
    frontend="frame",
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2,
        d_model=64,
        vocab_size=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
    )
