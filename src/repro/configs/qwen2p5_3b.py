"""qwen2.5-3b [dense] — GQA with QKV bias.
[hf:Qwen/Qwen2.5-0.5B; hf]  36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    vocab_size=151936,
    attention="gqa",
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    qkv_bias=True,
    d_ff=11008,
    mlp="swiglu",
    rope_theta=1000000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=2,
        d_model=64,
        vocab_size=512,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
    )
