"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427; hf]  26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    vocab_size=256000,
    attention="gqa",
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    mlp="swiglu",            # gated-GeLU in the paper; gate structure matches
    layer_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    lru_width=2560,
    conv_width=4,
    rope_theta=10000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(
        num_layers=6,            # 2 full (rglru, rglru, local_attn) groups
        d_model=64,
        vocab_size=512,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        window=16,
        lru_width=64,
    )
