"""Decoder assembly: pattern-based layer stacking, scan + remat, train loss,
and cached decode — one code path for all ten assigned architectures.

Layer pattern (cfg.layer_pattern, default by family) repeats over the depth;
the repeating groups are scan-stacked (compile time independent of depth),
any remainder/prefix layers are unrolled.  DeepSeek's leading dense-FFN
layer(s) are the ``prefix``; RecurrentGemma's (rglru, rglru, attn) pattern
scans over 3-layer groups.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from ..sharding import MeshContext, constrain
from . import blocks, ssm
from .common import (
    ParamSpec,
    abstract_params,
    cross_entropy_loss,
    init_params,
    param_shardings,
    rms_norm,
    stack_specs,
)

LABEL_PAD = -1


def layer_pattern(cfg: ArchConfig) -> tuple[str, ...]:
    if cfg.layer_pattern:
        return cfg.layer_pattern
    if cfg.family == "ssm":
        return ("ssm",)
    return ("attn",)


# ---------------------------------------------------------------------------
# per-layer specs / apply / cache
# ---------------------------------------------------------------------------

def _mixer_specs(kind: str, cfg: ArchConfig) -> dict:
    if kind in ("attn", "local_attn"):
        return blocks.mla_specs(cfg) if cfg.attention == "mla" else blocks.gqa_specs(cfg)
    if kind == "rglru":
        return ssm.rglru_specs(cfg)
    if kind == "ssm":
        return ssm.mamba2_specs(cfg)
    raise ValueError(kind)


def _layer_specs(kind: str, cfg: ArchConfig, *, moe: bool) -> dict:
    d = cfg.d_model
    specs = {
        "norm1": ParamSpec((d,), (None,), init="zeros"),
        "mixer": _mixer_specs(kind, cfg),
    }
    if kind != "ssm":  # mamba blocks have no separate FFN
        specs["norm2"] = ParamSpec((d,), (None,), init="zeros")
        specs["ffn"] = blocks.moe_specs(cfg) if moe else blocks.mlp_specs(cfg)
    return specs


def _apply_mixer(kind, p, x, cfg, ctx):
    if kind == "attn":
        if cfg.attention == "mla":
            return blocks.mla_attention(p, x, cfg, ctx)
        return blocks.gqa_attention(p, x, cfg, ctx)
    if kind == "local_attn":
        return blocks.gqa_attention(p, x, cfg, ctx, window=cfg.window)
    if kind == "rglru":
        return ssm.rglru_block(p, x, cfg, ctx)
    if kind == "ssm":
        return ssm.mamba2_block(p, x, cfg, ctx)
    raise ValueError(kind)


def _apply_layer(kind, p, x, cfg, ctx, *, moe: bool):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    x = x + _apply_mixer(kind, p["mixer"], h, cfg, ctx)
    if kind != "ssm":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        ffn = blocks.moe_block if moe else blocks.mlp
        x = x + ffn(p["ffn"], h, cfg, ctx)
    return x


def _mixer_decode(kind, p, x, cache, pos, cfg, ctx):
    if kind == "attn":
        if cfg.attention == "mla":
            return blocks.mla_decode(p, x, cache, pos, cfg, ctx)
        return blocks.gqa_decode(p, x, cache, pos, cfg, ctx)
    if kind == "local_attn":
        return blocks.gqa_decode(p, x, cache, pos, cfg, ctx, window=cfg.window)
    if kind == "rglru":
        return ssm.rglru_decode(p, x, cache, pos, cfg, ctx)
    if kind == "ssm":
        return ssm.mamba2_decode(p, x, cache, pos, cfg, ctx)
    raise ValueError(kind)


def _apply_layer_decode(kind, p, x, cache, pos, cfg, ctx, *, moe: bool):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    mixed, cache = _mixer_decode(kind, p["mixer"], h, cache, pos, cfg, ctx)
    x = x + mixed
    if kind != "ssm":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        ffn = blocks.moe_block if moe else blocks.mlp
        x = x + ffn(p["ffn"], h, cfg, ctx)
    return x, cache


def _mixer_cache(kind, cfg: ArchConfig, batch: int, max_len: int, dtype):
    if kind == "attn":
        if cfg.attention == "mla":
            return blocks.mla_init_cache(cfg, batch, max_len, dtype)
        return blocks.gqa_init_cache(cfg, batch, max_len, dtype)
    if kind == "local_attn":
        return blocks.gqa_init_cache(cfg, batch, min(cfg.window, max_len), dtype)
    if kind == "rglru":
        return ssm.rglru_init_cache(cfg, batch, dtype)
    if kind == "ssm":
        return ssm.mamba2_init_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# model specs / init
# ---------------------------------------------------------------------------

def _layer_plan(cfg: ArchConfig):
    """(prefix_kinds, pattern, groups, suffix_kinds): prefix layers are the
    leading dense-FFN layers; suffix is the non-divisible remainder."""
    pat = layer_pattern(cfg)
    prefix = cfg.first_dense_layers
    rest = cfg.num_layers - prefix
    groups, rem = divmod(rest, len(pat))
    return (pat[:1] * prefix, pat, groups, pat[:rem])


def model_specs(cfg: ArchConfig) -> dict:
    moe = cfg.num_experts > 0
    prefix_kinds, pat, groups, suffix_kinds = _layer_plan(cfg)
    specs: dict[str, Any] = {
        # embedding table: vocab-sharded only — FSDP on the d dim would
        # force an involuntary full remat around the token gather (SPMD)
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", None)),
        "final_norm": ParamSpec((cfg.d_model,), (None,), init="zeros"),
        # lm_head: keep the contracted d dim unsharded; vocab over model
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size), (None, "vocab")),
        "prefix": [
            _layer_specs(k, cfg, moe=False) for k in prefix_kinds
        ],
        "blocks": {
            f"s{i}": stack_specs(_layer_specs(k, cfg, moe=moe), groups)
            for i, k in enumerate(pat)
        } if groups else {},
        "suffix": [
            _layer_specs(k, cfg, moe=moe) for k in suffix_kinds
        ],
    }
    return specs


def init_model(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    return init_params(model_specs(cfg), key, dtype)


def abstract_model(cfg: ArchConfig, dtype=jnp.bfloat16):
    return abstract_params(model_specs(cfg), dtype)


def model_shardings(cfg: ArchConfig, ctx: MeshContext):
    return param_shardings(model_specs(cfg), ctx)


def count_params(cfg: ArchConfig) -> int:
    import numpy as np

    leaves = jax.tree_util.tree_leaves(
        model_specs(cfg),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    return int(sum(np.prod(s.shape) for s in leaves))


def count_active_params(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    if cfg.num_experts == 0:
        return count_params(cfg)
    total = count_params(cfg)
    f = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * f
    moe_layers = cfg.num_layers - cfg.first_dense_layers
    inactive = moe_layers * (cfg.num_experts - cfg.top_k) * per_expert
    return total - inactive


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: ArchConfig, ctx: MeshContext, *,
            remat_policy: str = "full", scan_unroll: int | bool = 1,
            last_token_only: bool = False):
    """Logits for a full sequence.  batch: {'tokens' (B,S)} or
    {'embeds' (B,S,d)} for stub-frontend archs.

    ``scan_unroll=True`` flattens the layer scan — used by the dry-run's
    cost-extrapolation compiles (XLA cost_analysis counts a while body once,
    so roofline terms are measured on shallow unrolled models and scaled)."""
    if cfg.frontend != "none" and "embeds" in batch:
        x = batch["embeds"]
    else:
        x = params["embed"][batch["tokens"]]
    x = constrain(x.astype(params["lm_head"].dtype), ctx, ("batch", None, None))

    moe = cfg.num_experts > 0
    prefix_kinds, pat, groups, suffix_kinds = _layer_plan(cfg)

    for p_layer, kind in zip(params["prefix"], prefix_kinds):
        x = _apply_layer(kind, p_layer, x, cfg, ctx, moe=False)

    if groups:
        def body(x, group_params):
            for i, kind in enumerate(pat):
                x = _apply_layer(kind, group_params[f"s{i}"], x, cfg, ctx, moe=moe)
            return x, None

        if remat_policy == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif remat_policy == "dots":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        x, _ = lax.scan(body, x, params["blocks"], unroll=scan_unroll)

    for p_layer, kind in zip(params["suffix"], suffix_kinds):
        x = _apply_layer(kind, p_layer, x, cfg, ctx, moe=moe)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if last_token_only:
        x = x[:, -1:, :]  # serving prefill: only the final position's logits
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return constrain(logits, ctx, ("batch", None, "act_model"))


def loss_fn(params, batch, cfg: ArchConfig, ctx: MeshContext, *,
            remat_policy: str = "full", scan_unroll: int | bool = 1):
    logits = forward(params, batch, cfg, ctx, remat_policy=remat_policy,
                     scan_unroll=scan_unroll)
    labels = batch["labels"]
    mask = labels != LABEL_PAD
    return cross_entropy_loss(logits, jnp.maximum(labels, 0), mask)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    prefix_kinds, pat, groups, suffix_kinds = _layer_plan(cfg)
    stack = lambda tree, n: jax.tree_util.tree_map(  # noqa: E731
        lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), tree
    )
    return {
        "prefix": [_mixer_cache(k, cfg, batch, max_len, dtype) for k in prefix_kinds],
        "blocks": {
            f"s{i}": stack(_mixer_cache(k, cfg, batch, max_len, dtype), groups)
            for i, k in enumerate(pat)
        } if groups else {},
        "suffix": [_mixer_cache(k, cfg, batch, max_len, dtype) for k in suffix_kinds],
    }


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, ctx: MeshContext,
                *, scan_unroll: int | bool = 1):
    """One decode step.  tokens (B, 1) int32; pos scalar int32.
    Returns (logits (B, V), new cache)."""
    x = params["embed"][tokens]
    x = constrain(x.astype(params["lm_head"].dtype), ctx, ("batch", None, None))
    moe = cfg.num_experts > 0
    prefix_kinds, pat, groups, suffix_kinds = _layer_plan(cfg)

    new_cache: dict[str, Any] = {"prefix": [], "blocks": {}, "suffix": []}
    for p_layer, kind, c in zip(params["prefix"], prefix_kinds, cache["prefix"]):
        x, c2 = _apply_layer_decode(kind, p_layer, x, c, pos, cfg, ctx, moe=False)
        new_cache["prefix"].append(c2)

    if groups:
        def body(x, scanned):
            group_params, group_cache = scanned
            cs = {}
            for i, kind in enumerate(pat):
                x, cs[f"s{i}"] = _apply_layer_decode(
                    kind, group_params[f"s{i}"], x, group_cache[f"s{i}"],
                    pos, cfg, ctx, moe=moe,
                )
            return x, cs

        x, scanned_cache = lax.scan(
            body, x, (params["blocks"], cache["blocks"]), unroll=scan_unroll
        )
        new_cache["blocks"] = scanned_cache

    for p_layer, kind, c in zip(params["suffix"], suffix_kinds, cache["suffix"]):
        x, c2 = _apply_layer_decode(kind, p_layer, x, c, pos, cfg, ctx, moe=moe)
        new_cache["suffix"].append(c2)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0]
    return constrain(logits, ctx, ("batch", "act_model")), new_cache
