"""Attention variants (GQA / sliding-window / MLA), MLPs, and MoE.

All functions are (params, x, ...) -> y with plain dict param pytrees, and
come in two modes:
  * train/prefill: full sequence, causal (optionally windowed) mask
  * decode: one new token against a KV cache at position ``pos``

Spec builders (``*_specs``) are the single source of truth for shapes and
logical sharding axes (models/common.ParamSpec).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..configs.base import ArchConfig
from ..sharding import MeshContext, constrain
from .common import ParamSpec, apply_rope, dense, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA attention (covers MHA and MQA; optional sliding window)
# ---------------------------------------------------------------------------

def gqa_specs(cfg: ArchConfig) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, H, hd), ("fsdp", "heads", "head_dim")),
        "wk": ParamSpec((d, Hkv, hd), ("fsdp", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, Hkv, hd), ("fsdp", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "fsdp")),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), init="zeros")
        specs["bk"] = ParamSpec((Hkv, hd), ("kv_heads", "head_dim"), init="zeros")
        specs["bv"] = ParamSpec((Hkv, hd), ("kv_heads", "head_dim"), init="zeros")
    return specs


def _attend(q, k, v, mask):
    """q (B,S,H,hd), k/v (B,T,Hkv,hd), mask (B,1,S,T) or (1,1,S,T) bool.
    Materialises the full (S, T) logits — decode/small-S path and the
    oracle for the chunked version below."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    q = q.reshape(B, S, Hkv, group, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                       logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, hd)


def _causal_mask(S, T, offset: int = 0, window: int = 0):
    """(1, 1, S, T) bool; q position i (global offset+i) sees keys j <= i,
    and j > i - window when window > 0."""
    qpos = offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, None]


ATTN_CHUNK = 1024  # KV-chunk length for the online-softmax path


def _attend_chunked(q, k, v, *, window: int = 0, chunk: int = ATTN_CHUNK):
    """Flash-style causal attention: lax.scan over KV chunks with an online
    softmax, so logits never exceed (B, Hkv, g, S, chunk).  This is what
    makes 32k-token prefill (and unsharded-head archs) fit HBM — the full
    (S, T) score matrix is never materialised.

    Self-attention layout: q (B,S,H,hd), k/v (B,S,Hkv,hd), same positions.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    nc = S // chunk
    qr = q.reshape(B, S, Hkv, group, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    kc = k.reshape(B, nc, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(S, dtype=jnp.int32)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, c0 = xs
        kpos = c0 * chunk + jnp.arange(chunk, dtype=jnp.int32)
        logits = jnp.einsum("bskgd,btkd->bkgst", qr, kb).astype(jnp.float32)
        logits = logits * scale
        mask = kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        # guard fully-masked rows (m_new == NEG_INF): keep weights at 0
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, group, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, group, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(nc, dtype=jnp.int32))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd).astype(q.dtype)


def gqa_attention(p, x, cfg: ArchConfig, ctx: MeshContext, *, window: int = 0,
                  positions=None):
    """Full-sequence causal attention.  x (B, S, d)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ctx, ("batch", None, "act_model", None))
    if S % ATTN_CHUNK == 0 and S > ATTN_CHUNK:
        out = _attend_chunked(q, k, v, window=window)
    else:
        out = _attend(q, k, v, _causal_mask(S, S, window=window))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return constrain(y, ctx, ("batch", None, None))


def gqa_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, Hkv, hd), dtype),
        "v": jnp.zeros((batch, max_len, Hkv, hd), dtype),
    }


def gqa_decode(p, x, cache, pos, cfg: ArchConfig, ctx: MeshContext, *,
               window: int = 0):
    """One-token decode.  x (B, 1, d); cache k/v (B, T, Hkv, hd); pos scalar
    int32 — the index of the new token.  Returns (y, cache)."""
    B = x.shape[0]
    T = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]).astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # windowed caches store key at pos % T (ring buffer); full caches at pos
    # (caches may be low-precision, e.g. fp8 — cast on write, upcast on read)
    cdt = cache["k"].dtype
    slot = jnp.mod(pos, T) if window > 0 else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cdt), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cdt), (0, slot, 0, 0))
    kpos = jnp.arange(T)
    if window > 0:
        # ring: entry j holds absolute position j + T*floor stuff; valid if
        # within the last ``window`` positions <= pos
        abs_pos = jnp.where(kpos <= slot, pos - slot + kpos,
                            pos - slot - T + kpos)
        mask = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - window)
    else:
        mask = kpos <= pos
    out = _attend(q, ck.astype(x.dtype), cv.astype(x.dtype),
                  mask[None, None, None, :])
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------

def mla_specs(cfg: ArchConfig) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    qn, qr, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    specs = {
        "kv_down": ParamSpec((d, kl + qr), ("fsdp", "kv_lora")),
        "kv_norm": ParamSpec((kl,), ("kv_lora",), init="zeros"),
        "k_up": ParamSpec((kl, H, qn), ("kv_lora", "heads", "head_dim")),
        "v_up": ParamSpec((kl, H, vd), ("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec((H, vd, d), ("heads", "head_dim", "fsdp")),
    }
    if ql > 0:
        specs["q_down"] = ParamSpec((d, ql), ("fsdp", "q_lora"))
        specs["q_norm"] = ParamSpec((ql,), ("q_lora",), init="zeros")
        specs["q_up"] = ParamSpec((ql, H, qn + qr), ("q_lora", "heads", "head_dim"))
    else:
        specs["q_proj"] = ParamSpec((d, H, qn + qr), ("fsdp", "heads", "head_dim"))
    return specs


def _mla_q(p, x, cfg: ArchConfig):
    if cfg.q_lora_rank > 0:
        cq = rms_norm(dense(x, p["q_down"]), p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsq,qhk->bshk", cq, p["q_up"]).astype(x.dtype)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["q_proj"]).astype(x.dtype)
    return jnp.split(q, [cfg.qk_nope_dim], axis=-1)  # nope, rope


def _mla_kv_latent(p, x, cfg: ArchConfig):
    ckv_full = dense(x, p["kv_down"])                     # (B,S,kl+qr)
    ckv, k_rope = jnp.split(ckv_full, [cfg.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    return ckv, k_rope


def _mla_attend(p, q_nope, q_rope, ckv, k_rope, cfg: ArchConfig, mask):
    """q_* (B,S,H,*); ckv (B,T,kl); k_rope (B,T,qr) already roped."""
    k_nope = jnp.einsum("btc,chk->bthk", ckv, p["k_up"]).astype(q_nope.dtype)
    v = jnp.einsum("btc,chk->bthk", ckv, p["v_up"]).astype(q_nope.dtype)
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    logits = (
        jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
        + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthk->bshk", probs, v)


def _mla_attend_chunked(p, q_nope, q_rope, ckv, k_rope, cfg: ArchConfig,
                        *, chunk: int = ATTN_CHUNK):
    """Flash-style MLA: expands each KV chunk from the latent on the fly —
    neither the (S, T) scores nor the full expanded K/V ever materialise."""
    B, S, H, _ = q_nope.shape
    nc = S // chunk
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    ckv_c = ckv.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    kr_c = k_rope.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    qpos = jnp.arange(S, dtype=jnp.int32)
    hd_v = cfg.v_head_dim

    def body(carry, xs):
        m, l, acc = carry
        ckv_b, kr_b, c0 = xs
        k_nope_b = jnp.einsum("btc,chk->bthk", ckv_b, p["k_up"]).astype(q_nope.dtype)
        v_b = jnp.einsum("btc,chk->bthk", ckv_b, p["v_up"]).astype(q_nope.dtype)
        logits = (
            jnp.einsum("bshk,bthk->bhst", q_nope, k_nope_b)
            + jnp.einsum("bshk,btk->bhst", q_rope, kr_b)
        ).astype(jnp.float32) * scale
        kpos = c0 * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        pw = jnp.exp(logits - m_new[..., None])
        pw = jnp.where(mask[None, None], pw, 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + pw.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bthk->bhsk", pw, v_b.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (ckv_c, kr_c, jnp.arange(nc, dtype=jnp.int32))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q_nope.dtype)  # (B,S,H,hd_v)


def mla_attention(p, x, cfg: ArchConfig, ctx: MeshContext, *, positions=None):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv, k_rope = _mla_kv_latent(p, x, cfg)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    if S % ATTN_CHUNK == 0 and S > ATTN_CHUNK:
        out = _mla_attend_chunked(p, q_nope, q_rope, ckv, k_rope, cfg)
    else:
        mask = _causal_mask(S, S)
        out = _mla_attend(p, q_nope, q_rope, ckv, k_rope, cfg, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return constrain(y, ctx, ("batch", None, None))


def mla_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode(p, x, cache, pos, cfg: ArchConfig, ctx: MeshContext):
    B = x.shape[0]
    cdt = cache["ckv"].dtype
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_new, k_rope_new = _mla_kv_latent(p, x, cfg)
    k_rope_new = apply_rope(k_rope_new, positions, cfg.rope_theta)
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cdt), (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_new.astype(cdt), (0, pos, 0))
    T = ckv.shape[1]
    mask = (jnp.arange(T) <= pos)[None, None, None, :]
    out = _mla_attend(p, q_nope, q_rope, ckv.astype(x.dtype),
                      k_rope.astype(x.dtype), cfg, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"]).astype(x.dtype)
    return y, {"ckv": ckv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    if cfg.mlp == "swiglu":
        return {
            "w_gate": ParamSpec((d, f), ("fsdp", "mlp")),
            "w_up": ParamSpec((d, f), ("fsdp", "mlp")),
            "w_down": ParamSpec((f, d), ("mlp", "fsdp")),
        }
    return {  # relu2 / gelu: single up-proj
        "w_up": ParamSpec((d, f), ("fsdp", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "fsdp")),
    }


def mlp(p, x, cfg: ArchConfig, ctx: MeshContext):
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(dense(x, p["w_gate"])) * dense(x, p["w_up"])
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(dense(x, p["w_up"])))
    else:
        h = jax.nn.gelu(dense(x, p["w_up"]))
    h = constrain(h, ctx, ("batch", None, "act_model"))
    return constrain(dense(h, p["w_down"]), ctx, ("batch", None, None))


# ---------------------------------------------------------------------------
# MoE: top-k routing, capacity drop, explicit EP/FSDP via shard_map
# ---------------------------------------------------------------------------
#
# Routing must stay LOCAL to each data shard (a pjit-level argsort over the
# sharded token dim would lower to a global sort).  So the routed part is a
# shard_map: tokens sharded over (pod, data) and replicated over 'model';
# expert weights sharded over 'model' (EP) and over 'data' on their d_model
# dim (FSDP, gathered per layer like ZeRO-3); each model rank serves its own
# experts and a single psum('model') combines — the same reduce a TP dense
# FFN pays, with zero all_to_all (DESIGN.md §6).

def moe_specs(cfg: ArchConfig) -> dict:
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    specs = {
        "router": ParamSpec((d, E), ("fsdp", None)),
        "w_gate": ParamSpec((E, d, f), ("experts", "fsdp", "expert_ff")),
        "w_up": ParamSpec((E, d, f), ("experts", "fsdp", "expert_ff")),
        "w_down": ParamSpec((E, f, d), ("experts", "expert_ff", "fsdp")),
    }
    if cfg.num_shared_experts > 0:
        shared_f = f * cfg.num_shared_experts
        specs["shared"] = mlp_specs(cfg.replace(mlp="swiglu"), shared_f)
    return specs


def _moe_local(xt, router, wg, wu, wd, *, cfg: ArchConfig, ctx: MeshContext,
               model_axis: str, ep_sharded: bool, fsdp_axes: tuple[str, ...],
               ff_axes: tuple[str, ...]):
    """shard_map body.  xt (Tl, d) local tokens; wg/wu (El, d_shard, f_shard);
    wd (El, f_shard, d_shard)."""
    E, k = cfg.num_experts, cfg.top_k
    Tl, d = xt.shape

    # ZeRO-3 gather of this layer's expert weights over the FSDP axes
    for ax in fsdp_axes:
        router = jax.lax.all_gather(router, ax, axis=0, tiled=True)
        wg = jax.lax.all_gather(wg, ax, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, ax, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, ax, axis=2, tiled=True)
    for ax in ff_axes:  # expert hidden dim sharded over pods
        wg = jax.lax.all_gather(wg, ax, axis=2, tiled=True)
        wu = jax.lax.all_gather(wu, ax, axis=2, tiled=True)
        wd = jax.lax.all_gather(wd, ax, axis=1, tiled=True)

    logits = jnp.einsum("td,de->te", xt, router).astype(jnp.float32)
    weights, experts = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    weights = (weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9, None))

    flat_expert = experts.reshape(Tl * k)
    flat_token = (
        jnp.repeat(jnp.arange(Tl, dtype=jnp.int32)[:, None], k, axis=1)
        .reshape(Tl * k)
    )
    flat_weight = weights.reshape(Tl * k)
    order = jnp.argsort(flat_expert)
    e_sorted = flat_expert[order]
    t_sorted = flat_token[order]
    w_sorted = flat_weight[order]

    # my expert range ([0, E) when experts are replicated over the mesh)
    El = wg.shape[0]
    me = jax.lax.axis_index(model_axis) if (ep_sharded and model_axis) else 0
    my_experts = me * El + jnp.arange(El, dtype=jnp.int32)

    C = max(1, int(cfg.capacity_factor * Tl * k / E))
    starts = jnp.searchsorted(e_sorted, my_experts, side="left").astype(jnp.int32)
    ends = jnp.searchsorted(e_sorted, my_experts, side="right").astype(jnp.int32)
    counts = ends - starts
    take = starts[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (El, C)
    valid = jnp.arange(C)[None, :] < jnp.minimum(counts, C)[:, None]
    take = jnp.clip(take, 0, Tl * k - 1)
    tok_idx = jnp.where(valid, t_sorted[take], 0)
    gate_w = jnp.where(valid, w_sorted[take], 0.0)

    xe = xt[tok_idx]                                           # (El, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
        "ecd,edf->ecf", xe, wu
    )
    ye = jnp.einsum("ecf,efd->ecd", h.astype(xt.dtype), wd)
    ye = ye * gate_w[..., None].astype(ye.dtype)
    ye = jnp.where(valid[..., None], ye, 0)

    y = jnp.zeros((Tl, d), xt.dtype).at[tok_idx.reshape(-1)].add(
        ye.reshape(El * C, d)
    )
    if ep_sharded and model_axis:
        y = jax.lax.psum(y, model_axis)
    return y


def moe_block(p, x, cfg: ArchConfig, ctx: MeshContext):
    B, S, d = x.shape
    bdp = ctx.batch_axes or None
    model_axis = ctx.model_axis or ""
    # shardings the spec system assigns to the expert weights
    wg_spec = ctx.spec_for(("experts", "fsdp", "expert_ff"), p["w_gate"].shape)
    espec, dspec, fspec = wg_spec[0], wg_spec[1], wg_spec[2]
    as_tuple = lambda s: (  # noqa: E731
        s if isinstance(s, tuple) else ((s,) if s else ())
    )
    fsdp_axes = as_tuple(dspec)
    ff_axes = as_tuple(fspec)

    body = functools.partial(
        _moe_local, cfg=cfg, ctx=ctx, model_axis=model_axis,
        ep_sharded=espec is not None, fsdp_axes=fsdp_axes, ff_axes=ff_axes,
    )
    xt = x.reshape(B * S, d)
    y = shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(
            P(bdp, None),                    # tokens
            P(dspec, None),                  # router (FSDP over data)
            P(espec, dspec, fspec),          # w_gate
            P(espec, dspec, fspec),          # w_up
            P(espec, fspec, dspec),          # w_down
        ),
        out_specs=P(bdp, None),
        check_vma=False,
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    y = constrain(y.reshape(B, S, d), ctx, ("batch", None, None))
    if cfg.num_shared_experts > 0:
        y = y + mlp(p["shared"], x, cfg.replace(mlp="swiglu"), ctx)
    return y
