"""State-space blocks: Mamba2 SSD (state-space duality) and RG-LRU (Griffin).

Both are sub-quadratic: training uses chunked/associative scans; decode keeps
an O(1) recurrent state, which is what makes the ``long_500k`` shape feasible
for these families (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ArchConfig
from ..sharding import MeshContext, constrain
from .common import ParamSpec, causal_conv1d, dense, rms_norm


# ---------------------------------------------------------------------------
# Mamba2 SSD (arXiv:2405.21060, ssd_minimal_discrete adapted to JAX)
# ---------------------------------------------------------------------------

def mamba2_specs(cfg: ArchConfig) -> dict:
    d, di, n, g = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    nh = di // cfg.ssm_headdim
    conv_ch = di + 2 * g * n
    return {
        # in_proj packs [z (gate), x, B, C, dt]
        "in_proj": ParamSpec(
            (d, 2 * di + 2 * g * n + nh), ("fsdp", "inner")
        ),
        "conv_w": ParamSpec((cfg.conv_width, conv_ch), ("conv", "inner")),
        "conv_b": ParamSpec((conv_ch,), ("inner",), init="zeros"),
        "A_log": ParamSpec((nh,), ("heads",), init="ones"),
        "D": ParamSpec((nh,), ("heads",), init="ones"),
        "dt_bias": ParamSpec((nh,), ("heads",), init="zeros"),
        "norm": ParamSpec((di,), ("inner",), init="zeros"),
        "out_proj": ParamSpec((di, d), ("inner", "fsdp")),
    }


def _segsum(x):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k], -inf j>i."""
    L = x.shape[-1]
    x = jnp.repeat(x[..., None], L, axis=-1)                  # (..., i, j)
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)
    x = jnp.where(mask, x, 0)
    x_segsum = jnp.cumsum(x, axis=-2)
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, x_segsum, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """SSD over chunks.  x (b, s, h, p); dt (b, s, h); A (h,) negative;
    B, C (b, s, g, n).  Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])

    xc, dtc = to_chunks(x), to_chunks(dt)
    Bc = jnp.repeat(to_chunks(B), rep, axis=3)                # (b,c,l,h,n)
    Cc = jnp.repeat(to_chunks(C), rep, axis=3)

    dA = dtc * A[None, None, None, :]                         # (b,c,l,h) <= 0
    dA_cs = jnp.cumsum(dA, axis=2)                            # within-chunk

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))            # (b,c,h,l,l)
    att = jnp.einsum("bclhn,bcshn,bchls->bchls", Cc, Bc, L)
    y_diag = jnp.einsum("bchls,bcshp,bcsh->bclhp", att, xc, dtc)

    # 2. chunk states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)       # (b,c,l,h)
    states = jnp.einsum("bclhn,bclh,bclh,bclhp->bchpn",
                        Bc, decay_states, dtc, xc)

    # 3. inter-chunk recurrence over c (associative scan on (decay, state))
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                 # (b,c,h)

    def combine(a, b_):
        d1, s1 = a
        d2, s2 = b_
        return d1 * d2, s2 + d2[..., None, None] * s1

    if initial_state is not None:
        states = jnp.concatenate([initial_state[:, None], states], axis=1)
        chunk_decay = jnp.concatenate(
            [jnp.ones((b, 1, h), chunk_decay.dtype), chunk_decay], axis=1
        )
        dec_sc, st_sc = lax.associative_scan(combine, (chunk_decay, states), axis=1)
        prev_states = st_sc[:, :-1]                           # state BEFORE chunk c
        final_state = st_sc[:, -1]
    else:
        dec_sc, st_sc = lax.associative_scan(combine, (chunk_decay, states), axis=1)
        prev_states = jnp.concatenate(
            [jnp.zeros_like(st_sc[:, :1]), st_sc[:, :-1]], axis=1
        )
        final_state = st_sc[:, -1]

    # 4. inter-chunk output
    state_decay_out = jnp.exp(dA_cs)                          # (b,c,l,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       Cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def _mamba2_project(p, x, cfg: ArchConfig):
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    nh = di // cfg.ssm_headdim
    zxbcdt = dense(x, p["in_proj"])
    z, xin, Bf, Cf, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1
    )
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(dt.dtype))
    return z, xin, Bf, Cf, dt


def mamba2_block(p, x, cfg: ArchConfig, ctx: MeshContext):
    """Full-sequence Mamba2 block.  x (B, S, d)."""
    Bsz, S, _ = x.shape
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    hd = cfg.ssm_headdim
    nh = di // hd
    z, xin, Bf, Cf, dt = _mamba2_project(p, x, cfg)
    conv_in = jnp.concatenate([xin, Bf, Cf], axis=-1)
    conv_out, _ = causal_conv1d(conv_in, p["conv_w"])
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(conv_out.dtype))
    xin, Bf, Cf = jnp.split(conv_out, [di, di + g * n], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (nh,)
    xh = xin.reshape(Bsz, S, nh, hd)
    Bh = Bf.reshape(Bsz, S, g, n)
    Ch = Cf.reshape(Bsz, S, g, n)
    y, _ = ssd_chunked(
        xh.astype(jnp.float32), dt.astype(jnp.float32), A,
        Bh.astype(jnp.float32), Ch.astype(jnp.float32), cfg.ssd_chunk,
    )
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"])
    return constrain(out, ctx, ("batch", None, None))


def mamba2_init_cache(cfg: ArchConfig, batch: int, dtype):
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    nh = di // cfg.ssm_headdim
    conv_ch = di + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_headdim, n), jnp.float32),
    }


def mamba2_decode(p, x, cache, pos, cfg: ArchConfig, ctx: MeshContext):
    """One-token recurrent step.  x (B, 1, d)."""
    Bsz = x.shape[0]
    di, n, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    hd = cfg.ssm_headdim
    nh = di // hd
    z, xin, Bf, Cf, dt = _mamba2_project(p, x, cfg)
    conv_in = jnp.concatenate([xin, Bf, Cf], axis=-1)
    conv_out, conv_state = causal_conv1d(conv_in, p["conv_w"], cache["conv"])
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(conv_out.dtype))
    xin, Bf, Cf = jnp.split(conv_out, [di, di + g * n], axis=-1)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(Bsz, nh, hd).astype(jnp.float32)
    Bh = jnp.repeat(Bf.reshape(Bsz, g, n), nh // g, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cf.reshape(Bsz, g, n), nh // g, axis=1).astype(jnp.float32)
    dts = dt.reshape(Bsz, nh).astype(jnp.float32)

    decay = jnp.exp(dts * A[None, :])                         # (B, nh)
    h_new = (
        cache["ssm"] * decay[:, :, None, None]
        + jnp.einsum("bh,bhn,bhp->bhpn", dts, Bh, xh)
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h_new)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(Bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"])
    return out, {"conv": conv_state, "ssm": h_new}


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427)
# ---------------------------------------------------------------------------

RG_LRU_C = 8.0


def rglru_specs(cfg: ArchConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "in_x": ParamSpec((d, w), ("fsdp", "inner")),
        "in_gate": ParamSpec((d, w), ("fsdp", "inner")),
        "conv_w": ParamSpec((cfg.conv_width, w), ("conv", "inner")),
        "conv_b": ParamSpec((w,), ("inner",), init="zeros"),
        "lambda_p": ParamSpec((w,), ("inner",), init="ones", scale=1.0),
        "w_a": ParamSpec((w, w), ("inner", None), init="small"),
        "b_a": ParamSpec((w,), ("inner",), init="zeros"),
        "w_i": ParamSpec((w, w), ("inner", None), init="small"),
        "b_i": ParamSpec((w,), ("inner",), init="zeros"),
        "out": ParamSpec((w, d), ("inner", "fsdp")),
    }


def _rglru_gates(p, xw):
    """log a_t (<=0) and gated input; xw (..., w)."""
    r = jax.nn.sigmoid(dense(xw, p["w_a"]) + p["b_a"].astype(xw.dtype))
    i = jax.nn.sigmoid(dense(xw, p["w_i"]) + p["b_i"].astype(xw.dtype))
    log_a = (
        -RG_LRU_C
        * jax.nn.softplus(p["lambda_p"].astype(jnp.float32))
        * r.astype(jnp.float32)
    )
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None))
    gated = mult * i.astype(jnp.float32) * xw.astype(jnp.float32)
    return a, gated


def rglru_block(p, x, cfg: ArchConfig, ctx: MeshContext):
    """Full-sequence Griffin recurrent block.  x (B, S, d)."""
    gate = jax.nn.gelu(dense(x, p["in_gate"]))
    xw = dense(x, p["in_x"])
    xw, _ = causal_conv1d(xw, p["conv_w"])
    xw = xw + p["conv_b"].astype(xw.dtype)
    a, gated = _rglru_gates(p, xw)

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return a1 * a2, h2 + a2 * h1

    _, h = lax.associative_scan(combine, (a, gated), axis=1)
    y = (h.astype(x.dtype) * gate)
    out = dense(y, p["out"])
    return constrain(out, ctx, ("batch", None, None))


def rglru_init_cache(cfg: ArchConfig, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, 1, w), jnp.float32),
    }


def rglru_decode(p, x, cache, pos, cfg: ArchConfig, ctx: MeshContext):
    gate = jax.nn.gelu(dense(x, p["in_gate"]))
    xw = dense(x, p["in_x"])
    xw, conv_state = causal_conv1d(xw, p["conv_w"], cache["conv"])
    xw = xw + p["conv_b"].astype(xw.dtype)
    a, gated = _rglru_gates(p, xw)
    h = a * cache["h"] + gated
    y = (h.astype(x.dtype) * gate)
    out = dense(y, p["out"])
    return out, {"conv": conv_state, "h": h}
