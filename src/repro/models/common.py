"""Module-less parameter system + shared layers (pure JAX, no flax).

A model is described by a pytree of ``ParamSpec`` (shape, logical axes,
initializer).  From the same spec tree we derive:
  * real parameters           (``init_params`` — smoke tests, examples)
  * abstract parameters       (``abstract_params`` — dry-run lowering)
  * shardings                 (``param_shardings`` — via sharding.MeshContext)

Apply functions consume plain dict pytrees, so models stay first-class JAX.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]           # logical axis names, len == ndim
    init: str = "normal"                   # 'normal' | 'zeros' | 'ones' | 'small'
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _make(spec: ParamSpec, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    scale = spec.scale
    if spec.init == "small":
        scale = spec.scale / max(1, int(np.sqrt(np.prod(spec.shape[:-1]) or 1)))
    return (jax.random.normal(key, spec.shape) * scale).astype(dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree, key, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_make(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(spec_tree, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree, is_leaf=is_spec
    )


def param_shardings(spec_tree, ctx):
    return jax.tree_util.tree_map(
        lambda s: ctx.sharding_for(s.axes, s.shape), spec_tree, is_leaf=is_spec
    )


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Spec tree for ``n`` scan-stacked copies of a layer."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n, *s.shape), (axis_name, *s.axes), s.init, s.scale),
        spec_tree, is_leaf=is_spec,
    )


# ---------------------------------------------------------------------------
# shared layers
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim) or (..., seq, head_dim);
    positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    if x.ndim == angles.ndim + 1:                              # heads present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits, labels, mask=None):
    """SPMD-friendly CE: all reductions stay sharded (vocab may be sharded).

    logits (B, S, V) any float dtype; labels (B, S) int32.  Returns mean loss
    over unmasked positions (float32).

    Memory note: the label selection uses a boolean iota comparison, never a
    float one-hot — a (B, S, V) f32 one-hot was the single biggest train-step
    temp at 150k-vocab scale (EXPERIMENTS.md §Perf, baseline-fix pass).
    """
    logits = logits.astype(jnp.float32)
    vmax = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(vmax)
    logsumexp = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    vocab = logits.shape[-1]
    is_label = (
        jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        == labels[..., None]
    )
    label_logit = jnp.sum(jnp.where(is_label, shifted, 0.0), axis=-1)
    nll = logsumexp - label_logit
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def dense(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def causal_conv1d(x, w, state=None):
    """Depthwise causal conv.  x (B, S, C), w (K, C).  With ``state``
    (B, K-1, C) given, performs a streaming step (S may be 1) and returns
    (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                    # (B, S+K-1, C)
    # windows: y[t] = sum_k w[k] * xp[t + k]
    y = sum(xp[:, k : k + x.shape[1], :] * w[k] for k in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else jnp.zeros_like(pad)
    return y.astype(x.dtype), new_state
