"""Pure-JAX model zoo: param-spec system (common), attention/MLP/MoE blocks,
SSM recurrences (Mamba2 SSD, RG-LRU), and the pattern-stacked decoder
(transformer) with train / cached-decode entry points."""
