"""Serving launcher: build a (distributed) FM index over a corpus and serve
batched count queries; optionally also serve LM decode.

    python -m repro.launch.serve --kind dna --n 65536 --batches 10
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="dna")
    ap.add_argument("--n", type=int, default=1 << 16)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--pattern-len", type=int, default=16)
    ap.add_argument("--engine", default="bitonic")
    args = ap.parse_args()

    from ..core import alphabet as al
    from ..core.dist_suffix_array import DistSAConfig
    from ..core.fm_index import PAD
    from ..core.pipeline import build_index
    from ..data.corpus import corpus

    toks = corpus(args.kind, args.n)
    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("parts",)) if ndev > 1 else None
    t0 = time.time()
    index = build_index(toks, mesh,
                        sa_config=DistSAConfig(engine=args.engine))
    print(f"index built over {len(toks)} tokens in {time.time() - t0:.1f}s")

    s = al.append_sentinel(toks)
    rng = np.random.default_rng(0)
    lats = []
    total = 0
    for _ in range(args.batches):
        pats = np.full((args.batch, args.pattern_len), PAD, np.int32)
        for i in range(args.batch):
            L = rng.integers(3, args.pattern_len)
            st = rng.integers(0, args.n - L - 1)
            pats[i, :L] = s[st : st + L]
        t0 = time.perf_counter()
        counts = np.asarray(index.count(pats))
        lats.append(time.perf_counter() - t0)
        total += int(counts.sum())
    lats.sort()
    print(
        f"{args.batches} batches of {args.batch}: "
        f"p50={lats[len(lats) // 2] * 1e3:.1f}ms "
        f"p99={lats[-1] * 1e3:.1f}ms  total_hits={total}"
    )


if __name__ == "__main__":
    main()
