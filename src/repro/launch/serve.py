"""Serving launcher: build (or restore) a (distributed) FM index over a
corpus and serve batched count queries; optionally checkpoint the built
index so later launches skip construction entirely.

    # build, checkpoint, serve
    python -m repro.launch.serve --kind dna --n 65536 --ckpt-dir /tmp/idx

    # restore the checkpoint (no build) and serve immediately
    python -m repro.launch.serve --kind dna --n 65536 --ckpt-dir /tmp/idx \
        --restore --batches 10

    # async frontend: admission-controlled queue, per-bucket p50/p99 SLOs
    python -m repro.launch.serve --kind dna --n 65536 --serve-async \
        --queue-depth 4096 --max-wait-ms 2 --slo-p99-ms 50

    # segmented catalog: build + save, then restore and APPEND new text
    # (BWT-merge compaction keeps the catalog small, no rebuild)
    python -m repro.launch.serve --kind dna --n 65536 --segments 2 \
        --ckpt-dir /tmp/cat
    python -m repro.launch.serve --ckpt-dir /tmp/cat --restore \
        --append new_tokens.npy --serve-async
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np
import jax


def main(argv=None):
    from ..configs.bwt_index import CONFIG as icfg

    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="dna")
    ap.add_argument("--n", type=int, default=1 << 16)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--pattern-len", type=int, default=16)
    ap.add_argument("--engine", default="bitonic")
    ap.add_argument("--ckpt-dir", default=icfg.ckpt_dir,
                    help="checkpoint the built index here (index_io format)")
    ap.add_argument("--ckpt-keep", type=int, default=icfg.ckpt_keep,
                    help="checkpoint steps to retain under --ckpt-dir")
    ap.add_argument("--restore", action="store_true",
                    help="restore from --ckpt-dir instead of building")
    ap.add_argument("--segments", type=int, default=0,
                    help="build a segmented catalog of this many segments "
                         "(0 = monolithic index); saved under --ckpt-dir "
                         "as a SegmentedIndex catalog")
    ap.add_argument("--append", action="append", default=[],
                    metavar="TOKENS_FILE",
                    help="append tokens (.npy, or .npz with a 'tokens' "
                         "array) to the restored/built segmented catalog; "
                         "repeatable.  Triggers the background BWT-merge "
                         "compaction policy, and re-saves to --ckpt-dir")
    ap.add_argument("--serve-async", action="store_true",
                    help="serve through the admission-controlled async "
                         "frontend (per-request submits, SLO metrics)")
    ap.add_argument("--queue-depth", type=int, default=icfg.serve_queue_depth,
                    help="admission bound: submits beyond this shed")
    ap.add_argument("--max-wait-ms", type=float,
                    default=icfg.serve_max_wait_ms,
                    help="flush coalescing window for the async frontend")
    ap.add_argument("--slo-p99-ms", type=float, default=icfg.serve_slo_p99_ms,
                    help="per-bucket p99 latency target for count queries")
    ap.add_argument("--slo-p99-ms-locate", type=float,
                    default=icfg.serve_slo_p99_ms_locate,
                    help="per-bucket p99 latency target for locate queries")
    ap.add_argument("--locate-frac", type=float, default=0.2,
                    help="fraction of async requests issued as locate")
    ap.add_argument("--fault-schedule", default=None, metavar="SPEC",
                    help="arm deterministic fault injection for this run: "
                         "comma-separated failpoint triggers like "
                         "'io.write:0,merge.mid:1' (repro.testing."
                         "faultinject).  The run then exercises the "
                         "recovery paths instead of the happy path; a "
                         "fault report prints on exit")
    args = ap.parse_args(argv)
    if args.segments > args.n:
        ap.error(f"--segments {args.segments} exceeds --n {args.n} "
                 "(every segment needs at least one token)")

    from ..testing import faultinject

    if args.fault_schedule:
        faultinject.arm(faultinject.FaultSchedule.parse(args.fault_schedule))
        print(f"fault schedule armed: {args.fault_schedule}")

    from ..core.dist_suffix_array import DistSAConfig
    from ..core.fm_index import PAD
    from ..core.index_io import (
        describe_index,
        latest_index_step,
        restore_index,
        save_index,
    )
    from ..core.pipeline import build_index
    from ..core.segments import SegmentedIndex
    from ..data.corpus import corpus

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("parts",)) if ndev > 1 else None

    def load_tokens(path):
        if path.endswith(".npz"):
            with np.load(path) as z:
                return np.asarray(z["tokens"], np.int32)
        return np.asarray(np.load(path), np.int32)

    appended = [load_tokens(p) for p in args.append]
    catalog_json = (os.path.join(args.ckpt_dir, "catalog.json")
                    if args.ckpt_dir else None)

    if args.restore:
        if not args.ckpt_dir:
            ap.error("--restore requires --ckpt-dir")
        t0 = time.time()
        if catalog_json and os.path.exists(catalog_json):
            index = SegmentedIndex.load(args.ckpt_dir)
            if index.degraded:
                for q in index.quarantined:
                    print(f"WARNING: segment {q['seg_id']} quarantined "
                          f"({q['reason']}); serving degraded")
            if not index.segments:
                ap.error(f"catalog under {args.ckpt_dir} has no healthy "
                         "segments left to serve")
            toks = np.concatenate([s.tokens for s in index.segments])
            args.n = len(toks)
            print(
                f"restored segmented catalog ({len(index.segments)} "
                f"segments, {index.total_tokens} tokens, "
                f"sigma={index.sigma}) in {time.time() - t0:.1f}s"
            )
        else:
            info = describe_index(args.ckpt_dir)
            # query patterns must be sampled from the corpus the index was
            # actually built over — the manifest knows its raw length
            if info.text_length - 1 != args.n:
                print(
                    f"--n {args.n} != checkpointed corpus size "
                    f"{info.text_length - 1}; using the checkpoint's size"
                )
                args.n = info.text_length - 1
            toks = corpus(args.kind, args.n)
            index = restore_index(args.ckpt_dir, mesh)
            print(
                f"restored {info.kind} index (n={info.length}, "
                f"sigma={info.sigma}, bits={info.bits}) "
                f"in {time.time() - t0:.1f}s"
            )
    elif args.segments > 0:
        toks = corpus(args.kind, args.n)
        t0 = time.time()
        index = SegmentedIndex.from_config(int(toks.max()) + 1, icfg)
        for chunk in np.array_split(toks, args.segments):
            index.append(chunk)
        print(
            f"segmented catalog built over {len(toks)} tokens "
            f"({args.segments} segments) in {time.time() - t0:.1f}s"
        )
    else:
        toks = corpus(args.kind, args.n)
        t0 = time.time()
        index = build_index(toks, mesh,
                            sa_config=DistSAConfig(engine=args.engine))
        print(f"index built over {len(toks)} tokens in {time.time() - t0:.1f}s")
        if args.ckpt_dir:
            t0 = time.time()
            latest = latest_index_step(args.ckpt_dir)
            step = save_index(args.ckpt_dir, index,
                              step=0 if latest is None else latest + 1,
                              keep=args.ckpt_keep)
            print(
                f"checkpointed to {args.ckpt_dir} step {step} "
                f"in {time.time() - t0:.1f}s"
            )

    segmented = isinstance(index, SegmentedIndex)
    if appended and not segmented:
        ap.error("--append requires a segmented catalog "
                 "(--segments N, or --restore of one)")
    if appended and not args.serve_async:
        # synchronous appends; the async path routes them through the
        # frontend's control queue instead (compaction between flushes)
        for extra in appended:
            index.append(extra)
            merges = index.maybe_compact()
            print(f"appended {len(extra)} tokens "
                  f"({merges} merge compactions, "
                  f"{len(index.segments)} segments)")
    if segmented and args.ckpt_dir and not args.serve_async:
        index.save(args.ckpt_dir)
        print(f"segmented catalog saved to {args.ckpt_dir}")

    # sample query patterns from every text source, so --append serving
    # (sync and async alike) exercises old and new segments
    sources = [toks] + appended
    rng = np.random.default_rng(0)

    def sample(active_sources):
        src = active_sources[int(rng.integers(len(active_sources)))]
        hi = min(args.pattern_len, len(src) - 1)
        L = int(rng.integers(3, hi)) if hi > 3 else max(1, hi)
        st = int(rng.integers(0, max(1, len(src) - L)))
        return src[st : st + L]

    if args.serve_async:
        import json

        from ..serving.engine import FMQueryServer
        from ..serving.frontend import AsyncQueryFrontend, Rejected

        server = FMQueryServer.from_config(index, icfg)
        can_locate = (getattr(index, "sa_sample_rate", 0)
                      or getattr(getattr(index, "fm", None),
                                 "sa_sample_rate", 0)) != 0

        with AsyncQueryFrontend(
            server, max_queue=args.queue_depth, max_wait_ms=args.max_wait_ms,
            slo_p99_ms={"count": args.slo_p99_ms,
                        "locate": args.slo_p99_ms_locate},
        ) as fe:
            futs = []
            total = args.batches * args.batch
            for _ in range(total // 2 if appended else total):
                kind = ("locate" if can_locate
                        and rng.random() < args.locate_frac else "count")
                futs.append(fe.submit(sample([toks]), kind))
            for extra in appended:
                # live growth between flushes: append + merge compaction
                # on the worker thread, queries keep flowing
                info = fe.append(extra).result()
                print(f"async-appended {info['appended']} tokens "
                      f"({info['merges']} merge compactions, "
                      f"{info['segments']} segments)")
            for _ in range(total - len(futs)):
                kind = ("locate" if can_locate
                        and rng.random() < args.locate_frac else "count")
                futs.append(fe.submit(sample(sources), kind))
            hits = shed = 0
            for f in futs:
                r = f.result()
                if isinstance(r, Rejected):
                    shed += 1
                else:
                    hits += r.count
            m = fe.metrics()
        if segmented and args.ckpt_dir:
            index.save(args.ckpt_dir)
            print(f"segmented catalog saved to {args.ckpt_dir}")
        print(json.dumps(m, indent=2))
        print(
            f"async-serve: {m['completed']} answered "
            f"({shed} shed) at {m['qps']:.0f} qps, total_hits={hits}"
        )
        if faultinject.active() is not None:
            print(f"fault report: {faultinject.active().report()}")
        return

    lats = []
    total = 0
    for _ in range(args.batches):
        pats = np.full((args.batch, args.pattern_len), PAD, np.int32)
        for i in range(args.batch):
            p = sample(sources)
            pats[i, : len(p)] = p
        t0 = time.perf_counter()
        counts = np.asarray(index.count(pats))
        lats.append(time.perf_counter() - t0)
        total += int(counts.sum())
    lats.sort()
    print(
        f"{args.batches} batches of {args.batch}: "
        f"p50={lats[len(lats) // 2] * 1e3:.1f}ms "
        f"p99={lats[-1] * 1e3:.1f}ms  total_hits={total}"
    )
    if faultinject.active() is not None:
        print(f"fault report: {faultinject.active().report()}")


if __name__ == "__main__":
    main()
