"""Production training launcher.

On a real TPU pod each host runs:

    python -m repro.launch.train --arch qwen2p5_3b --steps 10000 \
        --ckpt-dir gs://bucket/run1 --resume

In this CPU container it runs reduced configs on a 1-device mesh (the same
code path — mesh construction is the only difference), which is what the
integration test exercises.  jax.distributed.initialize() is called when a
cluster environment is detected (TPU pods set the env automatically).
"""

from __future__ import annotations

import argparse
import os

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced config (full configs need a pod)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # multi-host: initialize the distributed runtime when launched by a
    # cluster scheduler (GKE/TPU-VM set these; single process skips)
    if "JAX_COORDINATOR_ADDRESS" in os.environ:
        jax.distributed.initialize()

    from ..configs.base import get_config, get_reduced_config
    from ..data.corpus import corpus
    from ..data.loader import LoaderConfig, TokenLoader
    from ..launch.mesh import make_production_mesh
    from ..sharding import TRAIN_RULES, MeshContext, single_device_context
    from ..training.optimizer import AdamWConfig
    from ..training.train_loop import TrainConfig, train

    if args.reduced:
        cfg = get_reduced_config(args.arch)
        ctx = single_device_context()
    else:
        cfg = get_config(args.arch)
        ctx = MeshContext(make_production_mesh(), TRAIN_RULES)

    toks = corpus("english", 1 << 17) % (cfg.vocab_size - 1) + 1
    loader = TokenLoader(toks, LoaderConfig(args.batch, args.seq, args.seed))
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                        total_steps=args.steps),
        compress_grads=args.compress_grads,
        checkpoint_every=max(1, args.steps // 5),
    )
    res = train(cfg, ctx, tcfg, loader, args.steps, ckpt_dir=args.ckpt_dir,
                resume=args.resume, seed=args.seed)
    print(f"final loss {res['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
