import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/roofline analysis.

This is the proof that the distribution config is coherent without real
hardware (system-prompt deliverable (e)): a sharding mismatch, compile-time
OOM, or unsupported collective fails the cell.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    ... dryrun --arch qwen2p5_3b --shape train_4k --multi-pod both
    ... dryrun --arch bwt_index                                   # index build
    ... dryrun --list

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs.base import ARCH_IDS, get_config  # noqa: E402
from ..models import transformer as tf  # noqa: E402
from ..sharding import DECODE_RULES, TRAIN_RULES, MeshContext  # noqa: E402
from ..training.optimizer import AdamWConfig, adamw_update  # noqa: E402
from . import roofline as rf  # noqa: E402
from .mesh import make_index_mesh, make_production_mesh  # noqa: E402
from .specs import (  # noqa: E402
    SHAPES,
    batch_specs,
    cache_specs,
    opt_state_abstract,
    param_specs_abstract,
    shape_skip_reason,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _train_step_fn(cfg, ctx, unroll=1, n_micro=1, remat="full"):
    """Train step with gradient accumulation over ``n_micro`` microbatches —
    the standard fit lever for big models on 16GB chips: activation
    checkpoints and CE temps scale with the microbatch, grads accumulate in
    one f32 buffer (DESIGN.md §6)."""
    opt_cfg = AdamWConfig()

    def step(state, batch):
        params = state["params"]

        def loss_of(p, mb):
            return tf.loss_fn(p, mb, cfg, ctx, remat_policy=remat,
                              scan_unroll=unroll)

        if n_micro == 1:
            loss_val, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            from ..sharding import constrain

            def reshard(x):
                x = x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
                # keep the BATCH dim sharded (not the micro index) so each
                # scan iteration slices a replicated leading dim — without
                # this SPMD reshards every microbatch (involuntary remat)
                axes = (None, "batch") + (None,) * (x.ndim - 2)
                return constrain(x, ctx, axes)

            micro = jax.tree_util.tree_map(reshard, batch)

            def body(acc, mb):
                lv, g = jax.value_and_grad(loss_of)(params, mb)
                g32 = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc[1], g
                )
                return (acc[0] + lv, g32), None

            # derive the f32 accumulator FROM the params so SPMD shards it
            # like them (a bare jnp.zeros would be layout-free and risks
            # replication — a 13.6 GB/dev temp at qwen scale)
            zeros = jax.tree_util.tree_map(
                lambda p: (p * 0).astype(jnp.float32), params
            )
            (loss_sum, gsum), _ = jax.lax.scan(
                body, (jnp.float32(0), zeros), micro,
                unroll=bool(unroll is True),
            )
            loss_val = loss_sum / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)

        params, opt, _ = adamw_update(grads, state["opt"], params, opt_cfg)
        return {"params": params, "opt": opt}, loss_val

    return step


def _prefill_fn(cfg, ctx, unroll=1):
    def prefill(params, batch):
        # serving prefill returns only the final position's logits — the
        # full (B, 32k, V) logits tensor was the biggest prefill temp
        return tf.forward(params, batch, cfg, ctx, remat_policy="none",
                          scan_unroll=unroll, last_token_only=True)

    return prefill


def _micro_batches(cfg, shape: str, chips: int) -> int:
    """Pick the gradient-accumulation factor so per-device activation
    checkpoints stay ~<= 4GB: layers x tokens_local x d_model x 2B."""
    if SHAPES[shape]["kind"] != "train":
        return 1
    B, S = SHAPES[shape]["global_batch"], SHAPES[shape]["seq_len"]
    dp = max(1, chips // 16)  # data(-and-pod) shards; model axis is 16
    tokens_local = (B // dp) * S
    ckpt_bytes = cfg.num_layers * tokens_local * cfg.d_model * 2
    target = 2 * 1024**3
    n = 1
    # each microbatch must still shard over all dp ranks: dp | (B / n)
    while ckpt_bytes / n > target and (B // (2 * n)) % dp == 0:
        n *= 2
    return n


def _decode_fn(cfg, ctx, unroll=1):
    def decode(params, cache, tokens, pos):
        return tf.decode_step(params, cache, tokens, pos, cfg, ctx,
                              scan_unroll=unroll)

    return decode


def _with_groups(cfg, g: int):
    """Same prefix/suffix structure, ``g`` scanned groups."""
    from ..models.transformer import _layer_plan

    prefix, pat, _groups, suffix = _layer_plan(cfg)
    return cfg.replace(
        num_layers=len(prefix) + g * len(pat) + len(suffix)
    )


def lower_cell(arch: str, shape: str, *, multi_pod: bool, cfg=None,
               unroll: int | bool = 1, rules=None, remat: str = "full",
               n_micro: int | None = None, cache_dtype=None):
    """Returns (lowered, chips, meta) for one LM cell.  The keyword
    overrides (rules / remat / n_micro / cache_dtype) are the §Perf
    hillclimb levers."""
    cfg = cfg or get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    kind = SHAPES[shape]["kind"]
    if rules is None:
        rules = TRAIN_RULES if kind == "train" else DECODE_RULES
    ctx = MeshContext(mesh, rules)

    params = param_specs_abstract(cfg, ctx, jnp.bfloat16)
    batch = batch_specs(cfg, shape, ctx)

    if kind == "train":
        if n_micro is None:
            n_micro = _micro_batches(cfg, shape, chips)
        state = {"params": params, "opt": opt_state_abstract(params)}
        fn = jax.jit(_train_step_fn(cfg, ctx, unroll, n_micro, remat),
                     donate_argnums=(0,))
        lowered = fn.lower(state, batch)
    elif kind == "prefill":
        fn = jax.jit(_prefill_fn(cfg, ctx, unroll))
        lowered = fn.lower(params, batch)
    else:  # decode
        cache = cache_specs(cfg, shape, ctx, dtype=cache_dtype)
        tokens = batch["tokens"]
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(_decode_fn(cfg, ctx, unroll), donate_argnums=(1,))
        lowered = fn.lower(params, cache, tokens, pos)
    tokens_processed = (
        SHAPES[shape]["global_batch"] * SHAPES[shape]["seq_len"]
        if kind in ("train", "prefill") else SHAPES[shape]["global_batch"]
    )
    meta = {
        "arch": arch, "shape": shape, "kind": kind, "chips": chips,
        "tokens": tokens_processed,
        "model_flops": rf.model_flops(get_config(arch), tokens_processed),
    }
    return lowered, chips, meta


def lower_index_cell(shape_kind: str, *, multi_pod: bool):
    """The paper's workload: build = prefix doubling rounds; serve = batched
    FM counting.  Uses the flat 'parts' mesh over every chip."""
    from ..configs.bwt_index import CONFIG as icfg
    from ..core.dist_suffix_array import DistSAConfig, _isa_jit
    from ..core.dist_fm import DistFMIndex, _count_jit
    from ..core.fm_index import PAD

    mesh = make_index_mesh(multi_pod=multi_pod)
    parts = mesh.size
    n = icfg.n
    if shape_kind == "build":
        cfg = DistSAConfig(axis="parts", engine=icfg.engine,
                           capacity_factor=icfg.capacity_factor,
                           rounds=icfg.rounds, qgram=icfg.qgram,
                           qgram_words=icfg.qgram_words,
                           discard=icfg.discard, local_sort=icfg.local_sort)
        s = jax.ShapeDtypeStruct(
            (n,), jnp.int32,
            sharding=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("parts")),
        )
        lowered = _isa_jit.lower(s, icfg.sigma, cfg, parts, mesh)
        meta = {"arch": "bwt_index", "shape": f"build_n{n}", "kind": "build",
                "chips": parts, "tokens": n, "model_flops": 0.0}
        return lowered, parts, meta
    # serve
    m = n // parts
    r = icfg.sample_rate
    sharding = lambda spec: jax.sharding.NamedSharding(  # noqa: E731
        mesh, jax.sharding.PartitionSpec(*spec))
    arrays = (
        jax.ShapeDtypeStruct((n,), jnp.int32, sharding=sharding(("parts",))),
        jax.ShapeDtypeStruct((n // r, icfg.sigma), jnp.int32,
                             sharding=sharding(("parts", None))),
        jax.ShapeDtypeStruct((icfg.sigma,), jnp.int32, sharding=sharding((None,))),
        # byte alphabet (sigma 257) exceeds the packable range -> unpacked
        # layout with the replicated placeholder fused operand
        jax.ShapeDtypeStruct((1, 1), jnp.int32, sharding=sharding((None, None))),
    )
    patterns = jax.ShapeDtypeStruct(
        (icfg.query_batch, icfg.query_len), jnp.int32, sharding=sharding((None, None)),
    )
    aux = (r, icfg.sigma, n, parts, 0)
    lowered = _count_jit.lower(arrays, patterns, aux, mesh)
    meta = {"arch": "bwt_index", "shape": f"serve_b{icfg.query_batch}",
            "kind": "serve", "chips": parts, "tokens": icfg.query_batch,
            "model_flops": 0.0}
    return lowered, parts, meta


def _corrected_roofline(arch, shape, *, multi_pod, chips, meta):
    """XLA cost_analysis counts a while/scan body ONCE, so roofline terms
    come from two shallow UNROLLED compiles (1 and 2 scan groups) linearly
    extrapolated to the real depth (DESIGN.md §8)."""
    cfg = get_config(arch)
    from ..models.transformer import _layer_plan

    _, _, G, _ = _layer_plan(cfg)
    points = []
    for g in (1, 2):
        low, _, _ = lower_cell(
            arch, shape, multi_pod=multi_pod, cfg=_with_groups(cfg, g),
            unroll=True,
        )
        comp = low.compile()
        r = rf.analyze(comp, chips)
        points.append(r)
    r1, r2 = points

    def extrap(a, b):
        # deeper models can't cost less: fusion noise between the two aux
        # compiles occasionally gives b < a; floor at the observed points
        return max(a + (G - 1) * (b - a), a, b, 0.0)

    corrected = rf.Roofline(
        flops_per_device=extrap(r1.flops_per_device, r2.flops_per_device),
        bytes_per_device=extrap(r1.bytes_per_device, r2.bytes_per_device),
        collective_bytes_per_device=extrap(
            r1.collective_bytes_per_device, r2.collective_bytes_per_device
        ),
        collective_detail={
            "counts_per_group": {
                k: r2.collective_detail["counts"].get(k, 0)
                - r1.collective_detail["counts"].get(k, 0)
                for k in set(r1.collective_detail["counts"])
                | set(r2.collective_detail["counts"])
            },
            "bytes": {
                k: extrap(
                    r1.collective_detail["bytes"].get(k, 0),
                    r2.collective_detail["bytes"].get(k, 0),
                )
                for k in set(r1.collective_detail["bytes"])
                | set(r2.collective_detail["bytes"])
            },
        },
        chips=chips,
    )
    return corrected


def run_cell(arch: str, shape: str, *, multi_pod: bool, compile_: bool = True,
             correct_costs: bool = True):
    t0 = time.time()
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if arch == "bwt_index":
        lowered, chips, meta = lower_index_cell(shape, multi_pod=multi_pod)
    else:
        cfg = get_config(arch)
        reason = shape_skip_reason(cfg, shape)
        if reason:
            return {"arch": arch, "shape": shape, "mesh": mesh_name,
                    "status": "skipped", "reason": reason}
        lowered, chips, meta = lower_cell(arch, shape, multi_pod=multi_pod)
    lower_s = time.time() - t0
    result = dict(meta, mesh=mesh_name, status="lowered", lower_s=lower_s)
    if not compile_:
        return result

    t1 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = time.time() - t1
    result["status"] = "compiled"

    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # noqa: BLE001 - backend-dependent
        result["memory"] = {"error": str(e)}

    roof = rf.analyze(compiled, chips)
    result["roofline_raw"] = roof.to_dict()

    if arch != "bwt_index" and correct_costs:
        corrected = _corrected_roofline(
            arch, shape, multi_pod=multi_pod, chips=chips, meta=meta
        )
        result["roofline"] = corrected.to_dict()
    else:
        result["roofline"] = result["roofline_raw"]

    if meta.get("model_flops"):
        result["roofline"]["model_flops"] = meta["model_flops"]
        hw = result["roofline"]["flops_per_device"] * chips
        result["roofline"]["useful_flops_ratio"] = (
            meta["model_flops"] / hw if hw else None
        )
    return result


def save_result(res: dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{res['arch']}__{res['shape']}__{res['mesh']}.json"
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(res, f, indent=2, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    lm_archs = [a for a in ARCH_IDS if a != "bwt_index"]
    archs = lm_archs + ["bwt_index"] if args.arch == "all" else [args.arch]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]

    cells = []
    for arch in archs:
        shapes = (
            ["build", "serve"] if arch == "bwt_index"
            else (list(SHAPES) if args.shape == "all" else [args.shape])
        )
        for shape in shapes:
            for mp in pods:
                cells.append((arch, shape, mp))

    if args.list:
        for c in cells:
            print(c)
        return

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
        try:
            res = run_cell(arch, shape, multi_pod=mp,
                           compile_=not args.no_compile)
            save_result(res)
            r = res.get("roofline", {})
            print(
                f"[{res['status']:9s}] {tag}  "
                f"lower={res.get('lower_s', 0):.1f}s "
                f"compile={res.get('compile_s', 0):.1f}s "
                f"bottleneck={r.get('bottleneck', '-')}"
            , flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"[FAILED   ] {tag}", flush=True)
            traceback.print_exc()
            save_result({"arch": arch, "shape": shape,
                         "mesh": "2x16x16" if mp else "16x16",
                         "status": "failed",
                         "error": traceback.format_exc()})
    print(f"done, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
