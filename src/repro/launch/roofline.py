"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (DESIGN.md §8):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

``cost_analysis()`` of the SPMD-partitioned executable reports per-device
flops/bytes.  Collective bytes are not in cost_analysis: we parse the
post-SPMD HLO text and apply per-op byte formulas (ring all-reduce moves
~2x the shard, all-gather moves the output minus the local shard, etc.).
"""

from __future__ import annotations

import dataclasses
import re

import numpy as np

# TPU v5e constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (conservative single-link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(txt: str) -> int:
    """Total bytes of all array shapes in an HLO type string (handles
    tuples '(f32[..], s32[..])')."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_op: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device bytes moved by collectives, from post-SPMD HLO.

    Byte model (per device):
      all-gather      : output - input      (receives everyone else's shard)
      reduce-scatter  : input - output      (sends everything but its shard)
      all-reduce      : 2 * (input)         (ring: reduce-scatter+all-gather)
      all-to-all      : input               (sends its full buffer)
      collective-permute : input            (one send)
    """
    counts: dict[str, int] = {}
    by_op: dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        out_type, op = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start" or op == c.replace("-", "_"):
                kind = c
                break
        if kind is None:
            continue
        out_b = _shape_bytes(out_type)
        # operand types: everything inside the call parens (HLO sometimes
        # prints bare operand names — fall back to the output shape, which
        # equals the input for permute / all-to-all / all-reduce)
        args = line[line.index("(") :]
        in_b = _shape_bytes(args)
        if kind == "all-gather":
            moved = max(out_b - in_b, 0) if in_b else out_b
        elif kind == "reduce-scatter":
            moved = max(in_b - out_b, 0) if in_b else out_b
        elif kind == "all-reduce":
            moved = 2 * (in_b or out_b)
        else:  # all-to-all, collective-permute
            moved = in_b or out_b
        counts[kind] = counts.get(kind, 0) + 1
        by_op[kind] = by_op.get(kind, 0) + moved
    return CollectiveStats(counts, by_op)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_detail: dict
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap model: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_detail": self.collective_detail,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
        }


def analyze(compiled, chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes(compiled.as_text())
    return Roofline(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=float(stats.total_bytes),
        collective_detail={
            "counts": stats.counts, "bytes": stats.bytes_by_op
        },
        chips=chips,
    )


def model_flops(cfg, tokens: int) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) — the useful-compute
    yardstick for the HLO_FLOPs ratio."""
    from ..models.transformer import count_active_params

    return 6.0 * count_active_params(cfg) * tokens
