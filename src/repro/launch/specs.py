"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation anywhere: params/opt-state/caches/batches are abstract,
with NamedShardings attached so ``jit(...).lower()`` sees the production
layout.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import transformer as tf
from ..sharding import MeshContext
from ..training.optimizer import init_opt_state

# the assigned input-shape sets (LM shapes are seq_len x global_batch)
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def shape_skip_reason(cfg: ArchConfig, shape: str) -> str | None:
    """DESIGN.md §5 skip rules."""
    if shape == "long_500k" and not cfg.subquadratic:
        return (
            "pure full-attention arch: 0.5M-token decode needs sub-quadratic "
            "attention (skip per assignment; DESIGN.md §5)"
        )
    return None


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec)
    )


def _batch_axes_for(B: int, ctx: MeshContext):
    """batch sharding with divisibility fallback (long_500k has B=1: the
    data axis idles — documented single-stream latency shape)."""
    bdp = ctx.batch_axes
    if bdp and B % ctx.axis_size(bdp) == 0:
        return bdp
    for ax in bdp or ():
        if B % ctx.mesh.shape[ax] == 0 and ctx.mesh.shape[ax] > 1:
            return (ax,)
    return None


def batch_specs(cfg: ArchConfig, shape: str, ctx: MeshContext) -> dict:
    info = SHAPES[shape]
    B, S = info["global_batch"], info["seq_len"]
    bdp = _batch_axes_for(B, ctx)
    mesh = ctx.mesh
    if info["kind"] in ("train", "prefill"):
        out: dict[str, Any] = {}
        if cfg.frontend != "none":
            out["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                                 P(bdp, None, None))
        else:
            out["tokens"] = _sds((B, S), jnp.int32, mesh, P(bdp, None))
        if info["kind"] == "train":
            out["labels"] = _sds((B, S), jnp.int32, mesh, P(bdp, None))
        return out
    # decode: one new token; S is the cache length
    return {"tokens": _sds((B, 1), jnp.int32, mesh, P(bdp, None))}


def _cache_spec_for_path(path, leaf_shape, cfg: ArchConfig, ctx: MeshContext,
                         batch: int):
    """Sharding for one KV-cache leaf, by leaf name."""
    bdp = _batch_axes_for(batch, ctx)
    name = str(path[-1].key) if hasattr(path[-1], "key") else ""
    model = ctx.model_axis
    def fits(dim, ax):
        return ax and leaf_shape[dim] % ctx.mesh.shape[ax] == 0
    if name in ("k", "v"):          # (..., B, T, Hkv, hd)
        # shard the SEQ dim over model: divisible for every arch (32k % 16)
        # where head counts (1, 8, 24, 56...) often are not — the fix that
        # brought MHA decode caches under HBM (EXPERIMENTS.md §Perf)
        seq_ax = model if fits(len(leaf_shape) - 3, model) else None
        return P(*([None] * (len(leaf_shape) - 4)), bdp, seq_ax, None, None)
    if name in ("ckv", "k_rope"):   # (..., B, T, r)
        seq_ax = model if fits(len(leaf_shape) - 2, model) else None
        return P(*([None] * (len(leaf_shape) - 3)), bdp, seq_ax, None)
    if name == "conv":              # (..., B, K-1, C)
        ch_ax = model if fits(len(leaf_shape) - 1, model) else None
        return P(*([None] * (len(leaf_shape) - 3)), bdp, None, ch_ax)
    if name == "ssm":               # (..., B, nh, hd, state)
        h_ax = model if fits(len(leaf_shape) - 3, model) else None
        return P(*([None] * (len(leaf_shape) - 4)), bdp, h_ax, None, None)
    if name == "h":                 # (..., B, 1, w) rg-lru state
        w_ax = model if fits(len(leaf_shape) - 1, model) else None
        return P(*([None] * (len(leaf_shape) - 3)), bdp, None, w_ax)
    return P(*([None] * len(leaf_shape)))


def cache_specs(cfg: ArchConfig, shape: str, ctx: MeshContext, dtype=None):
    info = SHAPES[shape]
    B, S = info["global_batch"], info["seq_len"]
    cache_shapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, B, S, dtype or jnp.bfloat16)
    )
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    leaves = [
        _sds(l.shape, l.dtype, ctx.mesh,
             _cache_spec_for_path(path, l.shape, cfg, ctx, B))
        for path, l in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_specs_abstract(cfg: ArchConfig, ctx: MeshContext, dtype=jnp.bfloat16):
    """Abstract params with production shardings attached."""
    specs = tf.model_specs(cfg)
    shardings = tf.model_shardings(cfg, ctx)
    abstract = tf.abstract_model(cfg, dtype)
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings,
    )


def opt_state_abstract(params_abstract):
    """Abstract AdamW state (f32 m/v shaped+sharded like params)."""
    def f32_like(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding)

    return {
        "m": jax.tree_util.tree_map(f32_like, params_abstract),
        "v": jax.tree_util.tree_map(f32_like, params_abstract),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
