"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
artifacts in experiments/dryrun/.

    PYTHONPATH=src python -m repro.launch.report > experiments/roofline.md
"""

from __future__ import annotations

import glob
import json
import os

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def load_cells():
    cells = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


HBM_PER_CHIP = 16 * 1024**3  # TPU v5e


def dryrun_table(cells):
    rows = ["| arch | shape | mesh | status | HBM/device | fits 16GB | compile |",
            "|---|---|---|---|---|---|---|"]
    for c in cells:
        mem = c.get("memory", {})
        per_dev = None
        if isinstance(mem, dict) and "temp_size_in_bytes" in mem:
            # memory_analysis of the SPMD-partitioned module is per-device
            per_dev = (
                mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
            )
        status = c["status"]
        if status == "skipped":
            status = f"skipped ({c['reason'][:40]}...)"
        fits = "-" if per_dev is None else (
            "yes" if per_dev <= HBM_PER_CHIP else "**NO**"
        )
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {status} | "
            f"{fmt_bytes(per_dev)} | {fits} | {c.get('compile_s', 0):.0f}s |"
        )
    return "\n".join(rows)


def roofline_table(cells):
    rows = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "bottleneck | MODEL/HLO flops | step s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") != "compiled":
            continue
        r = c.get("roofline", {})
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{r.get('compute_s', 0):.4f} | {r.get('memory_s', 0):.4f} | "
            f"{r.get('collective_s', 0):.4f} | **{r.get('bottleneck')}** | "
            f"{ratio:.2f} | {r.get('step_time_s', 0):.3f} |"
            if ratio is not None else
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{r.get('compute_s', 0):.4f} | {r.get('memory_s', 0):.4f} | "
            f"{r.get('collective_s', 0):.4f} | **{r.get('bottleneck')}** | "
            f"- | {r.get('step_time_s', 0):.3f} |"
        )
    return "\n".join(rows)


def summarize(cells):
    n = {"compiled": 0, "skipped": 0, "failed": 0}
    for c in cells:
        n[c.get("status", "failed")] = n.get(c.get("status", "failed"), 0) + 1
    return n


def main():
    cells = load_cells()
    print("## Dry-run matrix\n")
    print(dryrun_table(cells))
    print("\n## Roofline terms\n")
    print(roofline_table(cells))
    print("\nsummary:", summarize(cells))


if __name__ == "__main__":
    main()
