import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower + analyse VARIANTS of one cell and print
the roofline deltas (hypothesis -> change -> measure loop).

    PYTHONPATH=src python -m repro.launch.perf qwen_train
    PYTHONPATH=src python -m repro.launch.perf musicgen_decode
    PYTHONPATH=src python -m repro.launch.perf bwt_build

Each variant is one experiment; JSON results land in experiments/perf/.
"""

import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..sharding import DECODE_RULES, TRAIN_RULES  # noqa: E402
from . import roofline as rf  # noqa: E402
from .dryrun import (  # noqa: E402
    _corrected_roofline,
    _with_groups,
    lower_cell,
    lower_index_cell,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "perf")


def _measure_lm(arch, shape, *, multi_pod=False, **overrides):
    """Corrected roofline for a variant (2-point unrolled extrapolation),
    plus the full-depth compile's memory analysis."""
    from ..configs.base import get_config
    from ..models.transformer import _layer_plan

    cfg = get_config(arch)
    _, _, G, _ = _layer_plan(cfg)
    mesh_chips = 512 if multi_pod else 256

    # full-depth compile: memory + proof
    low, chips, meta = lower_cell(arch, shape, multi_pod=multi_pod, **overrides)
    comp = low.compile()
    mem = comp.memory_analysis()

    points = []
    for g in (1, 2):
        lo, _, _ = lower_cell(
            arch, shape, multi_pod=multi_pod, cfg=_with_groups(cfg, g),
            unroll=True, **overrides,
        )
        points.append(rf.analyze(lo.compile(), chips))
    r1, r2 = points

    def extrap(a, b):
        return max(a + (G - 1) * (b - a), a, b, 0.0)

    roof = rf.Roofline(
        extrap(r1.flops_per_device, r2.flops_per_device),
        extrap(r1.bytes_per_device, r2.bytes_per_device),
        extrap(r1.collective_bytes_per_device, r2.collective_bytes_per_device),
        {
            "bytes": {
                k: extrap(r1.collective_detail["bytes"].get(k, 0),
                          r2.collective_detail["bytes"].get(k, 0))
                for k in set(r1.collective_detail["bytes"])
                | set(r2.collective_detail["bytes"])
            }
        },
        chips,
    )
    return {
        "roofline": roof.to_dict(),
        "memory_gb": {
            "args": mem.argument_size_in_bytes / 2**30,
            "temps": mem.temp_size_in_bytes / 2**30,
            "out": mem.output_size_in_bytes / 2**30,
        },
        "model_flops": meta["model_flops"],
    }


def _measure_index(**overrides):
    """Roofline of the bwt_index build with config overrides."""
    import repro.configs.bwt_index as bwt_mod

    orig = bwt_mod.CONFIG
    try:
        bwt_mod.CONFIG = orig.replace(**overrides)
        low, chips, meta = lower_index_cell("build", multi_pod=False)
        comp = low.compile()
        mem = comp.memory_analysis()
        roof = rf.analyze(comp, chips)
        return {
            "roofline": roof.to_dict(),
            "memory_gb": {
                "args": mem.argument_size_in_bytes / 2**30,
                "temps": mem.temp_size_in_bytes / 2**30,
            },
        }
    finally:
        bwt_mod.CONFIG = orig


def _report(name, variant, res):
    r = res["roofline"]
    print(
        f"[{name}/{variant}] compute={r['compute_s']:.4f}s "
        f"memory={r['memory_s']:.4f}s collective={r['collective_s']:.4f}s "
        f"-> {r['bottleneck']} step={r['step_time_s']:.4f}s "
        f"mem={res['memory_gb']}"
        , flush=True
    )
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}__{variant}.json"), "w") as f:
        json.dump(res, f, indent=2, default=str)


def qwen_train(variants=None):
    """Target: most collective-bound train cell (TP all-reduce dominated)."""
    name = "qwen_train"
    # v1 (refuted): batch only over (pod, data) left the 16 model ranks
    # computing the SAME tokens redundantly -> 16x compute/memory terms.
    # v2: batch over ALL axes (pure DP+FSDP — no tensor parallelism), vocab
    # unsharded (B/dev=1 keeps CE temps small).
    fsdp_v2 = dict(
        TRAIN_RULES,
        heads=(), kv_heads=(), mlp=(), inner=(), act_model=(), vocab=(),
        batch=("pod", "data", "model"),
        fsdp=("data",),
    )
    # v3: like v2 but embed/lm_head stay vocab-sharded over 'model' (their
    # optimizer states were 6.2 GB replicated in v2); activation logits keep
    # the batch dim on (pod,data,model) — spec_for drops the conflicting
    # vocab mapping automatically.
    fsdp_v3 = dict(fsdp_v2, vocab=("model",))
    all_variants = {
        "baseline": {},
        "dots_remat": {"remat": "dots"},
        "micro1": {"n_micro": 1},
        "fsdp_v2": {"rules": fsdp_v2},
        "fsdp_v2_dots": {"rules": fsdp_v2, "remat": "dots"},
        "fsdp_v3": {"rules": fsdp_v3},
        "fsdp_v3_dots": {"rules": fsdp_v3, "remat": "dots"},
    }
    for v, kw in all_variants.items():
        if variants and v not in variants:
            continue
        _report(name, v, _measure_lm("qwen2p5_3b", "train_4k", **kw))


def musicgen_decode(variants=None):
    """Target: worst roofline fraction (memory-bound MHA decode)."""
    name = "musicgen_decode"
    all_variants = {
        "baseline": {},
        "fp8_cache": {"cache_dtype": jnp.float8_e4m3fn},
    }
    for v, kw in all_variants.items():
        if variants and v not in variants:
            continue
        _report(name, v, _measure_lm("musicgen_medium", "decode_32k", **kw))


def bwt_build(variants=None):
    """Target: the paper's own workload (index construction)."""
    name = "bwt_build"
    all_variants = {
        "baseline": {},                       # 28 static rounds, cap 2.0
        "rounds10": {"rounds": 10},           # LCP-adaptive round budget
        "rounds10_cap125": {"rounds": 10, "capacity_factor": 1.25},
        "bitonic": {"engine": "bitonic", "rounds": 10},
    }
    for v, kw in all_variants.items():
        if variants and v not in variants:
            continue
        t0 = time.time()
        res = _measure_index(**kw)
        res["compile_s"] = time.time() - t0
        _report(name, v, res)


TARGETS = {
    "qwen_train": qwen_train,
    "musicgen_decode": musicgen_decode,
    "bwt_build": bwt_build,
}

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    chosen = sys.argv[2:] or None
    if which == "all":
        for fn in TARGETS.values():
            fn()
    else:
        TARGETS[which](chosen)
