"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run forces 512 host devices; tests and benches
must keep seeing 1).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: 16x16 = 256 chips per pod; 2 pods for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_index_mesh(*, multi_pod: bool = False, parts: int | None = None):
    """Flat mesh for the BWT index build: the sort network spans every chip
    (DESIGN.md §6), so all mesh axes collapse into one 'parts' axis."""
    if parts is None:
        parts = 512 if multi_pod else 256
    return jax.make_mesh((parts,), ("parts",))


def make_debug_mesh(devices: int | None = None):
    """Small (pod, data, model) mesh over however many (possibly forced-host)
    devices exist — used by distributed tests."""
    n = devices or len(jax.devices())
    if n == 1:
        return jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    if n % 2:
        raise ValueError(f"need an even device count, got {n}")
    model = 2
    rest = n // 2
    data = rest if rest % 2 else rest  # keep pod=1 unless n >= 8
    pod = 1
    if n >= 8:
        pod, data = 2, n // (2 * model)
    return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
