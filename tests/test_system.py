"""End-to-end behaviour tests for the paper's system (single device).

Correctness of SA/BWT/FM against naive oracles, the public pipeline API,
and the BWT-powered data pipeline features (dedup / contamination).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import alphabet as al
from repro.core.bwt import bwt, bwt_naive, inverse_bwt
from repro.core.fm_index import PAD, build_fm_index, count, count_naive
from repro.core.pipeline import build_index
from repro.core.suffix_array import suffix_array, suffix_array_naive


def _random_text(rng, n, sigma_hi=5):
    return al.append_sentinel(rng.integers(1, sigma_hi, n).astype(np.int32))


class TestSuffixArray:
    def test_banana(self):
        s = al.append_sentinel(al.encode_str("BANANA"))
        sa = suffix_array(jnp.asarray(s), al.sigma_of(s))
        assert np.array_equal(np.asarray(sa), suffix_array_naive(s))

    @pytest.mark.parametrize("seed", range(5))
    def test_random_vs_naive(self, seed):
        rng = np.random.default_rng(seed)
        s = _random_text(rng, int(rng.integers(2, 120)))
        sa = suffix_array(jnp.asarray(s), al.sigma_of(s))
        assert np.array_equal(np.asarray(sa), suffix_array_naive(s))

    def test_repetitive_text(self):
        # worst case for prefix doubling: long runs
        s = al.append_sentinel(np.tile([1, 1, 1, 2], 32).astype(np.int32))
        sa = suffix_array(jnp.asarray(s), al.sigma_of(s))
        assert np.array_equal(np.asarray(sa), suffix_array_naive(s))

    def test_all_same_char(self):
        s = al.append_sentinel(np.full(64, 3, np.int32))
        sa = suffix_array(jnp.asarray(s), al.sigma_of(s))
        assert np.array_equal(np.asarray(sa), suffix_array_naive(s))


class TestBWT:
    def test_banana_fig1(self):
        """Figure 1 of the paper gives BNN$AAA (I=3) with '$' sorted as the
        LARGEST symbol; under the modern FM-index convention ('$' smallest,
        which our implementation uses) the BWT of BANANA$ is ANNB$AA (I=4).
        Both are valid — verified against the rotation-sort oracle, and the
        inverse transform recovers the text (tested below)."""
        s = al.append_sentinel(al.encode_str("BANANA"))
        b, row = bwt(jnp.asarray(s), al.sigma_of(s))
        assert al.decode_str(np.asarray(b)) == "ANNBAA"  # $ dropped by decode
        assert np.asarray(b)[4] == al.SENTINEL  # $ in position 4 of ANNB$AA
        assert int(row) == 4

    @pytest.mark.parametrize("seed", range(5))
    def test_matches_rotation_sort(self, seed):
        rng = np.random.default_rng(seed + 100)
        s = _random_text(rng, int(rng.integers(2, 100)))
        b, row = bwt(jnp.asarray(s), al.sigma_of(s))
        nb, nrow = bwt_naive(s)
        assert np.array_equal(np.asarray(b), nb)
        assert int(row) == nrow

    @pytest.mark.parametrize("seed", range(5))
    def test_invertible(self, seed):
        """Paper §2.1: 'Among the most important properties of BWT, it is
        reversible.'"""
        rng = np.random.default_rng(seed + 200)
        s = _random_text(rng, int(rng.integers(2, 100)))
        sigma = al.sigma_of(s)
        b, row = bwt(jnp.asarray(s), sigma)
        rec = inverse_bwt(b, row, sigma)
        assert np.array_equal(np.asarray(rec), s)


class TestFMIndex:
    @pytest.mark.parametrize("sample_rate", [4, 16, 64])
    def test_counts_vs_naive(self, sample_rate):
        rng = np.random.default_rng(7)
        s = _random_text(rng, 300)
        sigma = al.sigma_of(s)
        b, row = bwt(jnp.asarray(s), sigma)
        fm = build_fm_index(b, row, sigma, sample_rate)
        pats = np.full((20, 6), PAD, np.int32)
        lens = rng.integers(1, 7, 20)
        for i, L in enumerate(lens):
            pats[i, :L] = rng.integers(1, 5, L)
        got = np.asarray(count(fm, jnp.asarray(pats)))
        want = [count_naive(s, pats[i, :lens[i]]) for i in range(20)]
        assert list(got) == want

    def test_empty_and_missing(self):
        s = al.append_sentinel(al.encode_str("BANANA"))
        sigma = al.sigma_of(s)
        b, row = bwt(jnp.asarray(s), sigma)
        fm = build_fm_index(b, row, sigma, 4)
        pats = np.full((1, 4), PAD, np.int32)
        pats[0, :2] = al.encode_str("XY")
        assert int(count(fm, jnp.asarray(pats))[0]) == 0


class TestPipeline:
    def test_single_device_counts(self):
        rng = np.random.default_rng(1)
        toks = rng.integers(1, 6, 400).astype(np.int32)
        idx = build_index(toks, sample_rate=8)
        pats = np.full((4, 3), PAD, np.int32)
        pats[0, :2] = [1, 2]
        pats[1, :1] = [5]
        pats[2, :3] = [1, 2, 3]
        pats[3, :1] = [1]
        s = al.append_sentinel(toks)
        want = [count_naive(s, pats[i][pats[i] != PAD]) for i in range(4)]
        assert list(np.asarray(idx.count(pats))) == want

    def test_padding_does_not_pollute(self):
        """Padding tokens must never match real-alphabet queries."""
        toks = np.full(10, 2, np.int32)  # tiny: heavy padding to 64-multiple
        idx = build_index(toks, sample_rate=64)
        assert idx.length > idx.text_length  # padding happened
        pats = np.full((2, 2), PAD, np.int32)
        pats[0, :2] = [2, 2]
        pats[1, :1] = [2]
        got = list(np.asarray(idx.count(pats)))
        assert got == [9, 10]


class TestDataHygiene:
    def test_dedup_flags_duplicates(self):
        from repro.data.dedup import build_corpus_index, duplicate_window_mask

        rng = np.random.default_rng(3)
        base = rng.integers(1, 5, 200).astype(np.int32)
        dup = np.concatenate([base, base[:50]])  # first 50 tokens repeat
        idx = build_corpus_index(dup, sample_rate=8)
        mask = duplicate_window_mask(idx, dup, window=16, stride=16)
        # windows fully inside the duplicated prefix must be flagged
        assert mask[:32].all()

    def test_contamination_detects_leak(self):
        from repro.data.dedup import build_corpus_index, contamination_report

        rng = np.random.default_rng(4)
        corpus = rng.integers(1, 5, 300).astype(np.int32)
        leaked = corpus[100:140].copy()
        clean = rng.integers(1, 5, 40).astype(np.int32) + 10  # disjoint alphabet
        idx = build_corpus_index(corpus, sample_rate=8)
        rep = contamination_report(idx, [leaked, clean], probe_len=16)
        assert 0 in rep["contaminated"]
        assert 1 not in rep["contaminated"]
