"""Per-kernel shape/dtype sweeps: every Pallas kernel (interpret mode on
CPU) against its ref.py pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


class TestCharHistogram:
    @pytest.mark.parametrize("n", [1024, 4096, 5000, 12345])
    @pytest.mark.parametrize("sigma", [6, 22, 257])
    def test_sweep(self, n, sigma):
        rng = np.random.default_rng(n + sigma)
        toks = rng.integers(0, sigma, n).astype(np.int32)
        got = ops.char_histogram(jnp.asarray(toks), sigma)
        want = ref.char_histogram_ref(jnp.asarray(toks), sigma)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_block_rows_variants(self):
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 17, 8192).astype(np.int32)
        for br in (1, 4, 16):
            got = ops.char_histogram(jnp.asarray(toks), 17, block_rows=br)
            want = ref.char_histogram_ref(jnp.asarray(toks), 17)
            assert np.array_equal(np.asarray(got), np.asarray(want))


class TestRerankScan:
    @pytest.mark.parametrize("n", [512, 2048, 3000])
    @pytest.mark.parametrize("vals", [3, 50, 100000])
    def test_sweep(self, n, vals):
        rng = np.random.default_rng(n + vals)
        r1 = rng.integers(0, vals, n).astype(np.int32)
        r2 = rng.integers(-1, vals, n).astype(np.int32)
        order = np.lexsort((r2, r1))
        r1, r2 = r1[order], r2[order]
        got_r, got_g = ops.rerank_scan(jnp.asarray(r1), jnp.asarray(r2))
        want_r, want_g = ref.rerank_scan_ref(jnp.asarray(r1), jnp.asarray(r2))
        assert np.array_equal(np.asarray(got_r), np.asarray(want_r))
        assert int(got_g) == int(want_g)

    def test_all_equal_pairs(self):
        r1 = np.zeros(1024, np.int32)
        r2 = np.zeros(1024, np.int32)
        got_r, got_g = ops.rerank_scan(jnp.asarray(r1), jnp.asarray(r2))
        assert int(got_g) == 1
        assert np.array_equal(np.asarray(got_r), np.zeros(1024, np.int32))

    def test_all_distinct(self):
        r1 = np.arange(1024, dtype=np.int32)
        r2 = np.zeros(1024, np.int32)
        got_r, got_g = ops.rerank_scan(jnp.asarray(r1), jnp.asarray(r2))
        assert int(got_g) == 1024
        assert np.array_equal(np.asarray(got_r), r1)

    @pytest.mark.parametrize("block", [256, 512, 1024])
    def test_block_sizes(self, block):
        rng = np.random.default_rng(block)
        r1 = np.sort(rng.integers(0, 9, 4096)).astype(np.int32)
        r2 = rng.integers(0, 9, 4096).astype(np.int32)
        order = np.lexsort((r2, r1))
        r1, r2 = r1[order], r2[order]
        got_r, got_g = ops.rerank_scan(jnp.asarray(r1), jnp.asarray(r2),
                                       block=block)
        want_r, want_g = ref.rerank_scan_ref(jnp.asarray(r1), jnp.asarray(r2))
        assert np.array_equal(np.asarray(got_r), np.asarray(want_r))
        assert int(got_g) == int(want_g)


class TestRadixHist:
    @pytest.mark.parametrize("shift", [0, 8, 16, 24])
    @pytest.mark.parametrize("n,block", [(2048, 1024), (8192, 2048), (4096, 128)])
    def test_sweep(self, shift, n, block):
        rng = np.random.default_rng(shift + n)
        keys = rng.integers(-(2**31), 2**31 - 1, n, dtype=np.int64).astype(np.int32)
        got = ops.radix_hist(jnp.asarray(keys), shift, block=block)
        want = ref.radix_hist_ref(jnp.asarray(keys), shift, block)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_histogram_sums_to_block(self):
        rng = np.random.default_rng(5)
        keys = rng.integers(0, 1000, 4096).astype(np.int32)
        got = np.asarray(ops.radix_hist(jnp.asarray(keys), 0, block=1024))
        assert (got.sum(axis=1) == 1024).all()


class TestRadixSort:
    """The full hist->scan->scatter LSD pipeline vs XLA's stable sort, in
    both the pure-jnp fallback and kernel interpret mode."""

    @pytest.mark.parametrize("impl", ["jnp", "interpret"])
    @pytest.mark.parametrize("n,bits", [(2048, 29), (5000, 17), (1024, 32)])
    def test_single_word(self, impl, n, bits):
        rng = np.random.default_rng(n + bits)
        keys = rng.integers(0, 1 << min(bits, 48), n).astype(np.uint64)
        keys = (keys & ((1 << bits) - 1)).astype(np.uint32)
        pay = np.arange(n, dtype=np.int32)
        got = ops.radix_sort((jnp.asarray(keys), jnp.asarray(pay)),
                             num_keys=1, key_bits=(bits,), impl=impl)
        want = ref.radix_sort_ref((jnp.asarray(keys), jnp.asarray(pay)), 1)
        for g, w in zip(got, want):
            assert np.array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("impl", ["jnp", "interpret"])
    def test_two_word_stability(self, impl):
        """Heavy ties across both words: stability must match lax.sort."""
        rng = np.random.default_rng(9)
        n = 3000
        hi = rng.integers(0, 7, n).astype(np.uint32)
        lo = rng.integers(0, 11, n).astype(np.uint32)
        pay = np.arange(n, dtype=np.int32)
        args = (jnp.asarray(hi), jnp.asarray(lo), jnp.asarray(pay))
        got = ops.radix_sort(args, num_keys=2, key_bits=(3, 4), impl=impl)
        want = ref.radix_sort_ref(args, 2)
        for g, w in zip(got, want):
            assert np.array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("impl", ["jnp", "interpret"])
    def test_saturated_keys_with_padding(self, impl):
        """Real keys equal to the field pad + an n that forces block
        padding: pads must stay strictly after the saturated real keys."""
        n = 1500  # not a multiple of the kernel block
        keys = np.full(n, (1 << 12) - 1, np.uint32)  # all saturate the field
        pay = np.arange(n, dtype=np.int32)
        got = ops.radix_sort((jnp.asarray(keys), jnp.asarray(pay)),
                             num_keys=1, key_bits=(12,), impl=impl)
        assert np.array_equal(np.asarray(got[0]), keys)
        assert np.array_equal(np.asarray(got[1]), pay)  # stable: untouched


class TestRankSelect:
    @pytest.mark.parametrize("nblocks,r,B", [(8, 64, 16), (32, 128, 64), (4, 256, 7)])
    @pytest.mark.parametrize("sigma", [5, 257])
    def test_sweep(self, nblocks, r, B, sigma):
        rng = np.random.default_rng(nblocks * r + B + sigma)
        bwt = rng.integers(0, sigma, (nblocks, r)).astype(np.int32)
        bidx = rng.integers(0, nblocks, B).astype(np.int32)
        c = rng.integers(0, sigma, B).astype(np.int32)
        cut = rng.integers(0, r + 1, B).astype(np.int32)
        args = [jnp.asarray(x) for x in (bwt, bidx, c, cut)]
        got = ops.rank_select(*args)
        want = ref.rank_select_ref(*args)
        assert np.array_equal(np.asarray(got), np.asarray(want))

    def test_full_block_cutoff(self):
        bwt = np.full((2, 64), 3, np.int32)
        got = ops.rank_select(
            jnp.asarray(bwt),
            jnp.asarray([0, 1], np.int32),
            jnp.asarray([3, 3], np.int32),
            jnp.asarray([64, 0], np.int32),
        )
        assert list(np.asarray(got)) == [64, 0]


class TestKernelIntegration:
    def test_rerank_consistent_with_suffix_array_round(self):
        """The rerank kernel reproduces one prefix-doubling re-rank."""
        from repro.core.suffix_array import rerank_from_sorted

        rng = np.random.default_rng(9)
        r1 = np.sort(rng.integers(0, 20, 2048)).astype(np.int32)
        r2 = rng.integers(-1, 20, 2048).astype(np.int32)
        order = np.lexsort((r2, r1))
        r1, r2 = r1[order], r2[order]
        kr, kg = ops.rerank_scan(jnp.asarray(r1), jnp.asarray(r2))
        cr, call_distinct = rerank_from_sorted(jnp.asarray(r1), jnp.asarray(r2))
        assert np.array_equal(np.asarray(kr), np.asarray(cr))
        assert (int(kg) == 2048) == bool(call_distinct)

    def test_char_histogram_matches_initial_ranks(self):
        """Kernel histogram + exclusive cumsum == the paper's Occ table."""
        from repro.core.suffix_array import initial_ranks

        rng = np.random.default_rng(10)
        s = rng.integers(0, 6, 4096).astype(np.int32)
        hist = np.asarray(ops.char_histogram(jnp.asarray(s), 6))
        occ = np.cumsum(hist) - hist
        want = np.asarray(initial_ranks(jnp.asarray(s), 6))
        assert np.array_equal(occ[s], want)
