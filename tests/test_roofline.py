"""Roofline machinery unit tests: the HLO collective-bytes parser against
hand-written HLO snippets, and term arithmetic."""

import numpy as np

from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    _shape_bytes,
    collective_bytes,
)

# operand types appear inline in XLA HLO text (as compiled.as_text() prints)
HLO_SNIPPET = """
HloModule test
ENTRY %main {
  %p0 = bf16[16,4096,128]{2,1,0} parameter(0)
  %ag = bf16[16,4096,2048]{2,1,0} all-gather(bf16[16,4096,128]{2,1,0} %p0), dimensions={2}
  %ar = f32[1024,1024]{1,0} all-reduce(f32[1024,1024]{1,0} %x), to_apply=%add
  %rs = f32[64,1024]{1,0} reduce-scatter(f32[1024,1024]{1,0} %y), dimensions={0}
  %a2a = s32[4096]{0} all-to-all(s32[4096]{0} %z)
  %cp = bf16[512,512]{1,0} collective-permute(bf16[512,512]{1,0} %w), source_target_pairs={{0,1}}
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)
}
"""


class TestShapeBytes:
    def test_simple(self):
        assert _shape_bytes("bf16[16,4096,128]") == 16 * 4096 * 128 * 2
        assert _shape_bytes("f32[1024,1024]") == 1024 * 1024 * 4
        assert _shape_bytes("s32[4096]") == 4096 * 4

    def test_tuple(self):
        t = "(f32[8,8]{1,0}, s32[8]{0})"
        assert _shape_bytes(t) == 8 * 8 * 4 + 8 * 4

    def test_scalar_and_unknown(self):
        assert _shape_bytes("f32[]") == 4
        assert _shape_bytes("token[]") == 0


class TestCollectiveParser:
    def test_counts_and_bytes(self):
        stats = collective_bytes(HLO_SNIPPET)
        assert stats.counts == {
            "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
            "all-to-all": 1, "collective-permute": 1,
        }
        ag_out = 16 * 4096 * 2048 * 2
        ag_in = 16 * 4096 * 128 * 2
        assert stats.bytes_by_op["all-gather"] == ag_out - ag_in
        assert stats.bytes_by_op["all-reduce"] == 2 * 1024 * 1024 * 4
        assert stats.bytes_by_op["reduce-scatter"] == (1024 - 64) * 1024 * 4
        assert stats.bytes_by_op["all-to-all"] == 4096 * 4
        assert stats.bytes_by_op["collective-permute"] == 512 * 512 * 2

    def test_ignores_non_collectives(self):
        stats = collective_bytes("%d = f32[128,128]{1,0} dot(%a, %b)")
        assert stats.total_bytes == 0
        assert stats.counts == {}


class TestRooflineTerms:
    def test_bottleneck_selection(self):
        r = Roofline(
            flops_per_device=PEAK_FLOPS,        # 1s compute
            bytes_per_device=HBM_BW / 2,        # 0.5s memory
            collective_bytes_per_device=ICI_BW * 2,  # 2s collective
            collective_detail={}, chips=256,
        )
        assert np.isclose(r.compute_s, 1.0)
        assert np.isclose(r.memory_s, 0.5)
        assert np.isclose(r.collective_s, 2.0)
        assert r.bottleneck == "collective"
        assert np.isclose(r.step_time_s, 2.0)

    def test_to_dict_roundtrip(self):
        r = Roofline(1.0, 2.0, 3.0, {"counts": {}}, 4)
        d = r.to_dict()
        assert d["chips"] == 4 and d["bottleneck"] in (
            "compute", "memory", "collective"
        )
