"""Randomized lifecycle conformance: deterministic-seed interleavings of
append / compact (cost-model auto, forced pairwise fold, forced k-way
walk, rebuild) / save / load / count / locate, asserted bit-identical
against a document-set oracle at EVERY step.

The invariant under test is the document semantics of ``SegmentedIndex``:
answers are a pure function of the append history — matches never span
documents, and compaction (any strategy) never changes any answer.  On
top of the answer oracle, every compaction step is shadow-run under ALL
FOUR strategies and the resulting merged indexes compared field-by-field:
``compact(strategy=s)`` for every s must be bit-identical to
``compact(strategy="rebuild")`` (the BWT-merge acceptance criterion —
covering the rebuild fallback paths whenever a drawn run is merge-
ineligible or context-order unsafe).

The matrix covers sigma in {2, 4, 16, 17} — the 2-bit/4-bit/unpacked
packing boundaries after the reserved pad slot — and both ``reserve_pad``
layouts (reserve off lets the effective alphabet vary per segment, which
exercises the rebuild fallback on mixed catalogs).
"""

import os
import shutil

import numpy as np
import pytest

from repro.core.fm_index import PAD, fm_mismatch
from repro.core.journal import CURRENT, GEN_FMT, GenerationJournal
from repro.core.segments import SegmentedIndex
from repro.testing import faultinject
from repro.testing.faultinject import FaultSchedule, InjectedFault

SAMPLE_RATE = 8
SA_SAMPLE_RATE = 4
# quantized so the whole suite reuses a handful of jit program shapes
DOC_LENS = (1, 3, 5, 8, 13, 21, 34)


class DocOracle:
    """Ground truth: the bag of appended documents in global coordinates."""

    def __init__(self):
        self.docs: list[tuple[np.ndarray, int]] = []
        self.total = 0

    def append(self, tokens):
        self.docs.append((np.asarray(tokens), self.total))
        self.total += len(tokens)

    def patterns(self, rng, B=8, L=5, sigma=4):
        """PAD-padded queries: mostly corpus substrings, some random (often
        absent, possibly out-of-segment-alphabet)."""
        pats = np.full((B, L), PAD, np.int32)
        lens = np.zeros(B, np.int64)
        for b in range(B):
            m = int(rng.integers(1, L + 1))
            lens[b] = m
            doc, _ = self.docs[int(rng.integers(len(self.docs)))]
            if rng.random() < 0.25 or len(doc) < m:
                pats[b, :m] = rng.integers(1, sigma, m)
            else:
                st = int(rng.integers(0, len(doc) - m + 1))
                pats[b, :m] = doc[st : st + m]
        return pats, lens

    def expected(self, pats, lens, k):
        B = pats.shape[0]
        counts = np.zeros(B, np.int64)
        pos = np.full((B, k), self.total, np.int64)
        kcnt = np.zeros(B, np.int64)
        for b in range(B):
            p = pats[b, : lens[b]]
            hits = []
            for doc, off in self.docs:
                if len(p) > len(doc):
                    continue
                w = np.lib.stride_tricks.sliding_window_view(doc, len(p))
                hits += (np.nonzero((w == p).all(axis=1))[0] + off).tolist()
            hits = sorted(hits)
            counts[b] = len(hits)
            kcnt[b] = min(len(hits), k)
            pos[b, : kcnt[b]] = hits[: kcnt[b]]
        return counts, pos, kcnt


def assert_fm_identical(a, b, ctx):
    assert not (diff := fm_mismatch(a, b)), (ctx, diff)


def check_answers(seg, oracle, rng, sigma, ctx):
    if not oracle.docs:
        return
    pats, lens = oracle.patterns(rng, sigma=sigma)
    k = 2 * oracle.total + 2  # no clipping: full position sets must match
    want_c, want_p, want_k = oracle.expected(pats, lens, k)
    got_c = seg.count(pats)
    assert np.array_equal(got_c, want_c), (ctx, "count")
    got_p, got_k = seg.locate(pats, k)
    assert np.array_equal(got_k, want_k), (ctx, "locate counts")
    assert np.array_equal(got_p, want_p), (ctx, "locate positions")


STRATEGIES = ("merge", "pairwise", "kway", "rebuild")


def shadow_compact_identical(seg, min_tokens, strategy, ctx):
    """Run compact under EVERY strategy (cost-model auto, forced pairwise
    fold, forced k-way walk, rebuild) from the same state; assert the
    merged segments come out bit-identical across all of them, then leave
    ``seg`` compacted with ``strategy``."""
    snap_segments, snap_next = list(seg.segments), seg._next_id
    before_ids = {s.seg_id for s in snap_segments}

    results = {}
    for strat in STRATEGIES:
        seg.segments, seg._next_id = list(snap_segments), snap_next
        seg._stacked_cache = None
        merged = seg.compact(min_tokens=min_tokens, strategy=strat)
        results[strat] = (merged, list(seg.segments), seg._next_id)
    segs_r = results["rebuild"][1]
    for strat in STRATEGIES[:-1]:
        assert results[strat][0] == results["rebuild"][0], (ctx, strat)
        segs_s = results[strat][1]
        assert len(segs_s) == len(segs_r), (ctx, strat)
        for sm, sr in zip(segs_s, segs_r):
            assert (sm.offset, sm.n_tokens, sm.docs) == \
                (sr.offset, sr.n_tokens, sr.docs), (ctx, strat)
            if sm.seg_id in before_ids:
                continue  # untouched segment, same object
            assert_fm_identical(sm.index.fm, sr.index.fm, (ctx, strat))
    merged, segments, next_id = results[strategy]
    seg.segments, seg._next_id = segments, next_id
    seg._stacked_cache = None
    return merged


@pytest.mark.parametrize("reserve_pad", [None, False],
                         ids=["reserve", "noreserve"])
@pytest.mark.parametrize("sigma", [2, 4, 16, 17])
def test_lifecycle_fuzz(sigma, reserve_pad, tmp_path):
    rng = np.random.default_rng(1000 * sigma + (0 if reserve_pad is None
                                                else 1))
    seg = SegmentedIndex(
        sigma, sample_rate=SAMPLE_RATE, sa_sample_rate=SA_SAMPLE_RATE,
        reserve_pad=reserve_pad, segment_min_tokens=64,
    )
    oracle = DocOracle()
    save_dir = str(tmp_path / "cat")
    compacts = 0

    for step in range(14):
        roll = rng.random()
        ctx = (sigma, reserve_pad, step)
        if not oracle.docs or roll < 0.45:
            m = int(rng.choice(DOC_LENS))
            toks = rng.integers(1, sigma, m).astype(np.int32)
            seg.append(toks)
            oracle.append(toks)
        elif roll < 0.70 and len(seg.segments) >= 2:
            strategy = STRATEGIES[int(rng.integers(len(STRATEGIES)))]
            # merge every current segment half the time, only small ones
            # the other half (exercises runs bounded by large segments)
            min_tokens = None if rng.random() < 0.5 else 40
            compacts += shadow_compact_identical(
                seg, min_tokens, strategy, ctx
            )
        elif roll < 0.85:
            seg.save(save_dir)
            seg = SegmentedIndex.load(save_dir)
            assert seg.total_tokens == oracle.total, ctx
        # every step ends in a full query cross-check
        check_answers(seg, oracle, rng, sigma, ctx)

    if compacts == 0:  # schedule rolled no compact: force one at the end
        while len(seg.segments) < 2:
            toks = rng.integers(1, sigma, DOC_LENS[2]).astype(np.int32)
            seg.append(toks)
            oracle.append(toks)
        compacts += shadow_compact_identical(
            seg, None, "merge", (sigma, reserve_pad, "forced")
        )
        check_answers(seg, oracle, rng, sigma,
                      (sigma, reserve_pad, "forced"))
    assert compacts >= 1
    # final save/load round-trip must preserve the document tables exactly
    seg.save(save_dir)
    loaded = SegmentedIndex.load(save_dir)
    assert loaded.catalog() == seg.catalog()
    check_answers(loaded, oracle, rng, sigma, (sigma, reserve_pad, "final"))


def _files_on_disk(directory):
    return {
        os.path.relpath(os.path.join(root, f), directory).replace(os.sep, "/")
        for root, dirs, fs in os.walk(directory)
        if "quarantine" not in root.split(os.sep)
        for f in fs
    }


def _assert_no_orphans(directory, manifest):
    """The directory holds EXACTLY the committed generation's artifacts
    plus the journal bookkeeping — no staged debris, nothing missing."""
    expected = set(manifest["files"]) | {
        CURRENT, "catalog.json", GEN_FMT.format(manifest["generation"]),
    }
    got = _files_on_disk(directory)
    assert got == expected, ("orphans/missing", got ^ expected)


class TestCrashRecovery:
    """Crash-at-every-failpoint sweep over a catalog save: the reopened
    catalog must answer bit-for-bit as EITHER the pre-save or the post-save
    committed generation (atomicity — never a blend), with zero orphaned
    files after recovery."""

    SIGMA = 4

    @pytest.fixture(scope="class")
    def crash_state(self, tmp_path_factory):
        """(seg, base_dir, oracle_pre, oracle_post): ``base_dir`` holds
        committed generation 0 (two documents); ``seg`` carries a third
        appended document plus a compaction that generation 1 would
        commit."""
        tmp = tmp_path_factory.mktemp("crash")
        rng = np.random.default_rng(99)
        seg = SegmentedIndex(self.SIGMA, sample_rate=SAMPLE_RATE,
                             sa_sample_rate=SA_SAMPLE_RATE,
                             segment_min_tokens=256)
        pre, post = DocOracle(), DocOracle()
        docs = [rng.integers(1, self.SIGMA, m).astype(np.int32)
                for m in (21, 13, 34)]
        for d in docs[:2]:
            seg.append(d)
            pre.append(d)
            post.append(d)
        base = str(tmp / "base")
        seg.save(base)
        assert GenerationJournal(base).committed()["generation"] == 0
        # generation 1 will drop both old segments for one merged segment
        seg.append(docs[2])
        post.append(docs[2])
        assert seg.compact(min_tokens=None) == 1
        return seg, base, pre, post

    def test_crash_at_every_failpoint_recovers(self, crash_state, tmp_path):
        seg, base, pre, post = crash_state
        rng = np.random.default_rng(7)

        # discovery pass: a record-only schedule counts how many times each
        # failpoint fires during this exact save, so the sweep is exhaustive
        scratch = str(tmp_path / "scratch")
        shutil.copytree(base, scratch)
        with faultinject.inject(FaultSchedule()) as rec:
            seg.save(scratch)
        hits = dict(rec.hits)
        assert set(hits) >= {"io.write", "io.fsync", "io.rename"}, hits

        gens_seen = set()
        for name in sorted(hits):
            for k in range(hits[name]):
                ctx = (name, k)
                trial = str(tmp_path / f"t_{name.replace('.', '_')}_{k}")
                shutil.copytree(base, trial)
                with faultinject.inject(FaultSchedule([(name, k)])):
                    with pytest.raises(InjectedFault):
                        seg.save(trial)
                back = SegmentedIndex.load(trial)
                man = GenerationJournal(trial).committed()
                assert not back.degraded, (ctx, back.quarantined)
                if man["generation"] == 0:  # crash before the pointer flip
                    assert back.total_tokens == pre.total, ctx
                    check_answers(back, pre, rng, self.SIGMA, ctx)
                else:  # crash after commit (e.g. in the legacy mirror)
                    assert man["generation"] == 1, ctx
                    assert back.total_tokens == post.total, ctx
                    assert len(back.segments) == 1, ctx
                    check_answers(back, post, rng, self.SIGMA, ctx)
                gens_seen.add(man["generation"])
                _assert_no_orphans(trial, man)
        # the sweep must cover both sides of the commit point
        assert gens_seen == {0, 1}, gens_seen

    def test_crashed_save_retries_to_a_clean_commit(self, crash_state,
                                                    tmp_path):
        seg, base, _, post = crash_state
        rng = np.random.default_rng(8)
        trial = str(tmp_path / "retry")
        shutil.copytree(base, trial)
        with faultinject.inject(FaultSchedule([("io.rename", 0)])):
            with pytest.raises(InjectedFault):
                seg.save(trial)
        seg.save(trial)  # the retry must fully commit generation 1
        man = GenerationJournal(trial).committed()
        assert man["generation"] == 1
        back = SegmentedIndex.load(trial)
        assert back.total_tokens == post.total and not back.degraded
        check_answers(back, post, rng, self.SIGMA, "retry")
        _assert_no_orphans(trial, man)

    def test_first_save_crash_then_retry(self, tmp_path):
        """A crash during the very FIRST save leaves no committed
        generation (nothing to roll back to); the retried save succeeds."""
        rng = np.random.default_rng(5)
        seg = SegmentedIndex(self.SIGMA, sample_rate=SAMPLE_RATE,
                             sa_sample_rate=SA_SAMPLE_RATE)
        seg.append(rng.integers(1, self.SIGMA, 21).astype(np.int32))
        d = str(tmp_path / "cat")
        with faultinject.inject(FaultSchedule([("io.write", 0)])):
            with pytest.raises(InjectedFault):
                seg.save(d)
        assert GenerationJournal(d).committed() is None
        seg.save(d)
        back = SegmentedIndex.load(d)
        assert back.total_tokens == seg.total_tokens
        assert not back.degraded

    def test_merge_crash_leaves_operands_serving(self, tmp_path):
        """A crash mid BWT-merge (``merge.mid``) must leave the operand
        segments untouched and answering; the retried compact succeeds
        with invariant answers.  (Forced pairwise: the cost model would
        pick the rebuild for a run this small and never hit the merge
        failpoint.)"""
        rng = np.random.default_rng(6)
        seg = SegmentedIndex(self.SIGMA, sample_rate=SAMPLE_RATE,
                             sa_sample_rate=SA_SAMPLE_RATE,
                             compact_strategy="pairwise")
        oracle = DocOracle()
        for m in (21, 13):
            d = rng.integers(1, self.SIGMA, m).astype(np.int32)
            seg.append(d)
            oracle.append(d)
        ids_before = [s.seg_id for s in seg.segments]
        with faultinject.inject(FaultSchedule([("merge.mid", 0)])):
            with pytest.raises(InjectedFault):
                seg.compact(min_tokens=None)
        assert [s.seg_id for s in seg.segments] == ids_before
        check_answers(seg, oracle, rng, self.SIGMA, "post-crash")
        assert seg.compact(min_tokens=None) == 1
        check_answers(seg, oracle, rng, self.SIGMA, "post-retry")

    def test_kway_crash_leaves_operands_serving(self, tmp_path):
        """A crash mid k-way merge (``merge.kway``, hit only by the k-way
        walk) must leave the operand segments untouched and the previously
        committed generation loadable; the retried compact succeeds with
        invariant answers."""
        rng = np.random.default_rng(9)
        seg = SegmentedIndex(self.SIGMA, sample_rate=SAMPLE_RATE,
                             sa_sample_rate=SA_SAMPLE_RATE,
                             compact_strategy="kway")
        oracle = DocOracle()
        for m in (21, 13, 34):
            d = rng.integers(1, self.SIGMA, m).astype(np.int32)
            seg.append(d)
            oracle.append(d)
        base = str(tmp_path / "base")
        seg.save(base)
        gen0 = GenerationJournal(base).committed()["generation"]
        ids_before = [s.seg_id for s in seg.segments]
        with faultinject.inject(FaultSchedule([("merge.kway", 0)])):
            with pytest.raises(InjectedFault):
                seg.compact(min_tokens=None)
        # in-memory operands untouched and answering
        assert [s.seg_id for s in seg.segments] == ids_before
        check_answers(seg, oracle, rng, self.SIGMA, "post-kway-crash")
        # the committed generation still serves bit-for-bit
        back = SegmentedIndex.load(base)
        assert GenerationJournal(base).committed()["generation"] == gen0
        assert not back.degraded
        check_answers(back, oracle, rng, self.SIGMA, "prior-generation")
        # retry compacts through the k-way walk (no fallback) exactly
        assert seg.compact(min_tokens=None) == 1
        assert seg.compact_strategy_counts.get("kway", 0) == 1
        check_answers(seg, oracle, rng, self.SIGMA, "post-retry")


class TestQuarantine:
    """Corrupt artifacts are withdrawn from serving, not fatal: the catalog
    comes up degraded, healthy segments keep answering, and appends never
    reuse a quarantined segment's global coordinates."""

    SIGMA = 4

    def _saved(self, tmp_path, rng):
        seg = SegmentedIndex(self.SIGMA, sample_rate=SAMPLE_RATE,
                             sa_sample_rate=SA_SAMPLE_RATE)
        docs = [rng.integers(1, self.SIGMA, m).astype(np.int32)
                for m in (21, 34)]
        for d in docs:
            seg.append(d)
        directory = str(tmp_path / "cat")
        seg.save(directory)
        return seg, docs, directory

    def test_bitrot_quarantined_and_serving_degrades(self, tmp_path):
        rng = np.random.default_rng(31)
        seg, docs, directory = self._saved(tmp_path, rng)
        # flip one byte of the second segment's tokens (size unchanged:
        # only the CRC32 in the generation manifest can catch it)
        victim = os.path.join(directory, "seg_000001", "tokens.npz")
        blob = bytearray(open(victim, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        with open(victim, "wb") as f:
            f.write(bytes(blob))

        back = SegmentedIndex.load(directory)
        assert back.degraded
        assert [q["seg_id"] for q in back.quarantined] == [1]
        assert "crc32" in back.quarantined[0]["reason"]
        assert len(back.segments) == 1
        # forensics: the corrupt artifact moved under quarantine/, and the
        # healthy part of the catalog has no orphans around it
        qdir = os.path.join(directory, "quarantine")
        assert os.path.isdir(qdir) and os.listdir(qdir)

        # the healthy document still answers exactly
        pat = docs[0][3:8][None, :].astype(np.int32)
        want = np.count_nonzero([
            np.array_equal(docs[0][i:i + 5], docs[0][3:8])
            for i in range(len(docs[0]) - 4)
        ])
        assert back.count(pat)[0] == want
        # quarantined coordinates leave a hole: locate's fill sentinel and
        # new appends both sit past it
        assert back.coord_end == len(docs[0]) + len(docs[1])
        pos, _ = back.locate(pat, 4)
        assert pos.max() <= back.coord_end
        new = rng.integers(1, self.SIGMA, 13).astype(np.int32)
        appended = back.append(new)
        assert appended.offset == len(docs[0]) + len(docs[1])

    def test_injected_checksum_fault_quarantines(self, tmp_path):
        """The ``restore.checksum`` failpoint simulates a torn read during
        verification: the affected segment quarantines, the rest serve."""
        rng = np.random.default_rng(32)
        seg, docs, directory = self._saved(tmp_path, rng)
        with faultinject.inject(FaultSchedule([("restore.checksum", 0)])):
            back = SegmentedIndex.load(directory)
        assert back.degraded and len(back.quarantined) == 1
        assert "injected" in back.quarantined[0]["reason"]
        assert len(back.segments) == 1
        # quarantine is conservative: the implicated artifacts were MOVED
        # under quarantine/, so a later reload sees them as missing and the
        # catalog stays degraded — same healthy set, stable reason
        fresh = SegmentedIndex.load(directory)
        healthy = back.segments[0].seg_id
        assert [s.seg_id for s in fresh.segments] == [healthy]
        assert fresh.degraded and "missing" in fresh.quarantined[0]["reason"]

    def test_degraded_catalog_roundtrips_through_save(self, tmp_path):
        """Saving a degraded catalog commits only the healthy segments (the
        hole persists in coordinates), and reloads non-degraded."""
        rng = np.random.default_rng(33)
        seg, docs, directory = self._saved(tmp_path, rng)
        with faultinject.inject(FaultSchedule([("restore.checksum", 0)])):
            back = SegmentedIndex.load(directory)
        assert back.degraded
        end = back.coord_end
        out = str(tmp_path / "resaved")
        back.save(out)
        again = SegmentedIndex.load(out)
        assert not again.degraded
        assert again.total_tokens == back.total_tokens
        assert again.coord_end == end  # the hole survives the round-trip


def test_fuzz_compaction_of_compactions():
    """Repeated merge-of-merged segments (multi-document right operands,
    the wrap-correction path) stay exact and bit-identical to rebuild."""
    sigma = 4
    rng = np.random.default_rng(7)
    seg = SegmentedIndex(sigma, sample_rate=SAMPLE_RATE,
                         sa_sample_rate=SA_SAMPLE_RATE)
    oracle = DocOracle()
    for round_ in range(4):
        for _ in range(3):
            m = int(rng.choice(DOC_LENS))
            # adversarial: repeat one document often so merged texts are
            # periodic (order of prefix-pair suffixes depends on context)
            toks = (np.full(m, 1, np.int32) if rng.random() < 0.4
                    else rng.integers(1, sigma, m).astype(np.int32))
            seg.append(toks)
            oracle.append(toks)
        shadow_compact_identical(seg, None, "merge", round_)
        assert len(seg.segments) == 1 and seg.segments[0].multi_doc
        check_answers(seg, oracle, rng, sigma, round_)
