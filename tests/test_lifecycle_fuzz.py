"""Randomized lifecycle conformance: deterministic-seed interleavings of
append / compact(merge) / compact(rebuild) / save / load / count / locate,
asserted bit-identical against a document-set oracle at EVERY step.

The invariant under test is the document semantics of ``SegmentedIndex``:
answers are a pure function of the append history — matches never span
documents, and compaction (either strategy) never changes any answer.  On
top of the answer oracle, every compaction step is shadow-run with the
OTHER strategy and the resulting merged indexes compared field-by-field:
``compact(strategy="merge")`` must be bit-identical to
``compact(strategy="rebuild")`` (the BWT-merge acceptance criterion).

The matrix covers sigma in {2, 4, 16, 17} — the 2-bit/4-bit/unpacked
packing boundaries after the reserved pad slot — and both ``reserve_pad``
layouts (reserve off lets the effective alphabet vary per segment, which
exercises the rebuild fallback on mixed catalogs).
"""

import numpy as np
import pytest

from repro.core.fm_index import PAD, fm_mismatch
from repro.core.segments import SegmentedIndex

SAMPLE_RATE = 8
SA_SAMPLE_RATE = 4
# quantized so the whole suite reuses a handful of jit program shapes
DOC_LENS = (1, 3, 5, 8, 13, 21, 34)


class DocOracle:
    """Ground truth: the bag of appended documents in global coordinates."""

    def __init__(self):
        self.docs: list[tuple[np.ndarray, int]] = []
        self.total = 0

    def append(self, tokens):
        self.docs.append((np.asarray(tokens), self.total))
        self.total += len(tokens)

    def patterns(self, rng, B=8, L=5, sigma=4):
        """PAD-padded queries: mostly corpus substrings, some random (often
        absent, possibly out-of-segment-alphabet)."""
        pats = np.full((B, L), PAD, np.int32)
        lens = np.zeros(B, np.int64)
        for b in range(B):
            m = int(rng.integers(1, L + 1))
            lens[b] = m
            doc, _ = self.docs[int(rng.integers(len(self.docs)))]
            if rng.random() < 0.25 or len(doc) < m:
                pats[b, :m] = rng.integers(1, sigma, m)
            else:
                st = int(rng.integers(0, len(doc) - m + 1))
                pats[b, :m] = doc[st : st + m]
        return pats, lens

    def expected(self, pats, lens, k):
        B = pats.shape[0]
        counts = np.zeros(B, np.int64)
        pos = np.full((B, k), self.total, np.int64)
        kcnt = np.zeros(B, np.int64)
        for b in range(B):
            p = pats[b, : lens[b]]
            hits = []
            for doc, off in self.docs:
                if len(p) > len(doc):
                    continue
                w = np.lib.stride_tricks.sliding_window_view(doc, len(p))
                hits += (np.nonzero((w == p).all(axis=1))[0] + off).tolist()
            hits = sorted(hits)
            counts[b] = len(hits)
            kcnt[b] = min(len(hits), k)
            pos[b, : kcnt[b]] = hits[: kcnt[b]]
        return counts, pos, kcnt


def assert_fm_identical(a, b, ctx):
    assert not (diff := fm_mismatch(a, b)), (ctx, diff)


def check_answers(seg, oracle, rng, sigma, ctx):
    if not oracle.docs:
        return
    pats, lens = oracle.patterns(rng, sigma=sigma)
    k = 2 * oracle.total + 2  # no clipping: full position sets must match
    want_c, want_p, want_k = oracle.expected(pats, lens, k)
    got_c = seg.count(pats)
    assert np.array_equal(got_c, want_c), (ctx, "count")
    got_p, got_k = seg.locate(pats, k)
    assert np.array_equal(got_k, want_k), (ctx, "locate counts")
    assert np.array_equal(got_p, want_p), (ctx, "locate positions")


def shadow_compact_identical(seg, min_tokens, strategy, ctx):
    """Run compact under BOTH strategies from the same state; assert the
    merged segments come out bit-identical, then leave ``seg`` compacted
    with ``strategy``."""
    snap_segments, snap_next = list(seg.segments), seg._next_id
    before_ids = {s.seg_id for s in snap_segments}

    results = {}
    for strat in ("merge", "rebuild"):
        seg.segments, seg._next_id = list(snap_segments), snap_next
        seg._stacked_cache = None
        merged = seg.compact(min_tokens=min_tokens, strategy=strat)
        results[strat] = (merged, list(seg.segments), seg._next_id)
    assert results["merge"][0] == results["rebuild"][0], ctx
    segs_m, segs_r = results["merge"][1], results["rebuild"][1]
    assert len(segs_m) == len(segs_r), ctx
    for sm, sr in zip(segs_m, segs_r):
        assert (sm.offset, sm.n_tokens, sm.docs) == \
            (sr.offset, sr.n_tokens, sr.docs), ctx
        if sm.seg_id in before_ids:
            continue  # untouched segment, same object
        assert_fm_identical(sm.index.fm, sr.index.fm, ctx)
    merged, segments, next_id = results[strategy]
    seg.segments, seg._next_id = segments, next_id
    seg._stacked_cache = None
    return merged


@pytest.mark.parametrize("reserve_pad", [None, False],
                         ids=["reserve", "noreserve"])
@pytest.mark.parametrize("sigma", [2, 4, 16, 17])
def test_lifecycle_fuzz(sigma, reserve_pad, tmp_path):
    rng = np.random.default_rng(1000 * sigma + (0 if reserve_pad is None
                                                else 1))
    seg = SegmentedIndex(
        sigma, sample_rate=SAMPLE_RATE, sa_sample_rate=SA_SAMPLE_RATE,
        reserve_pad=reserve_pad, segment_min_tokens=64,
    )
    oracle = DocOracle()
    save_dir = str(tmp_path / "cat")
    compacts = 0

    for step in range(14):
        roll = rng.random()
        ctx = (sigma, reserve_pad, step)
        if not oracle.docs or roll < 0.45:
            m = int(rng.choice(DOC_LENS))
            toks = rng.integers(1, sigma, m).astype(np.int32)
            seg.append(toks)
            oracle.append(toks)
        elif roll < 0.70 and len(seg.segments) >= 2:
            strategy = "merge" if rng.random() < 0.7 else "rebuild"
            # merge every current segment half the time, only small ones
            # the other half (exercises runs bounded by large segments)
            min_tokens = None if rng.random() < 0.5 else 40
            compacts += shadow_compact_identical(
                seg, min_tokens, strategy, ctx
            )
        elif roll < 0.85:
            seg.save(save_dir)
            seg = SegmentedIndex.load(save_dir)
            assert seg.total_tokens == oracle.total, ctx
        # every step ends in a full query cross-check
        check_answers(seg, oracle, rng, sigma, ctx)

    if compacts == 0:  # schedule rolled no compact: force one at the end
        while len(seg.segments) < 2:
            toks = rng.integers(1, sigma, DOC_LENS[2]).astype(np.int32)
            seg.append(toks)
            oracle.append(toks)
        compacts += shadow_compact_identical(
            seg, None, "merge", (sigma, reserve_pad, "forced")
        )
        check_answers(seg, oracle, rng, sigma,
                      (sigma, reserve_pad, "forced"))
    assert compacts >= 1
    # final save/load round-trip must preserve the document tables exactly
    seg.save(save_dir)
    loaded = SegmentedIndex.load(save_dir)
    assert loaded.catalog() == seg.catalog()
    check_answers(loaded, oracle, rng, sigma, (sigma, reserve_pad, "final"))


def test_fuzz_compaction_of_compactions():
    """Repeated merge-of-merged segments (multi-document right operands,
    the wrap-correction path) stay exact and bit-identical to rebuild."""
    sigma = 4
    rng = np.random.default_rng(7)
    seg = SegmentedIndex(sigma, sample_rate=SAMPLE_RATE,
                         sa_sample_rate=SA_SAMPLE_RATE)
    oracle = DocOracle()
    for round_ in range(4):
        for _ in range(3):
            m = int(rng.choice(DOC_LENS))
            # adversarial: repeat one document often so merged texts are
            # periodic (order of prefix-pair suffixes depends on context)
            toks = (np.full(m, 1, np.int32) if rng.random() < 0.4
                    else rng.integers(1, sigma, m).astype(np.int32))
            seg.append(toks)
            oracle.append(toks)
        shadow_compact_identical(seg, None, "merge", round_)
        assert len(seg.segments) == 1 and seg.segments[0].multi_doc
        check_answers(seg, oracle, rng, sigma, round_)
