"""Parity suite for the fused-key build engine: every knob combination of
the fast builder (fused pair keys / radix local sort / packed q-gram init /
active-suffix discarding) must reproduce the seed prefix-doubling oracle
bit-for-bit — SA, BWT, and downstream count()/locate().  Plus the pad-key
regression tests for the unsigned packed layout (ISSUE 2 satellites)."""

import numpy as np
import jax.numpy as jnp
import pytest
from jax import lax

from repro.core import alphabet as al
from repro.core import keypack
from repro.core.bwt import bwt_from_sa, bwt_naive
from repro.core.suffix_array import (
    OVERFLOW_RANK,
    build_isa_fast,
    isa_prefix_doubling,
    sa_from_isa,
    suffix_array_fast,
)

SIGMAS = [2, 4, 20, 64]
ENGINES = ["compare", "radix"]


def _corpus(sigma_hi: int, n: int, seed: int = 0) -> np.ndarray:
    """Sentinel-terminated text over [1, sigma_hi); repetitive for tiny
    alphabets so several doubling rounds actually execute."""
    rng = np.random.default_rng(seed + sigma_hi + n)
    if sigma_hi <= 2:
        toks = np.ones(n - 1, np.int32)            # unary: worst repetition
    else:
        toks = rng.integers(1, sigma_hi, n - 1).astype(np.int32)
    return al.append_sentinel(toks)


class TestKeypack:
    @pytest.mark.parametrize("n", [2, 3, 1000, 40000, 65535, 100000])
    def test_roundtrip_and_order(self, n):
        rng = np.random.default_rng(n)
        spec = keypack.pair_spec(n)
        r1 = rng.integers(0, n, 512).astype(np.int32)
        r2 = rng.integers(-1, n, 512).astype(np.int32)
        words = keypack.pack_pairs(jnp.asarray(r1), jnp.asarray(r2), spec)
        u1, u2 = keypack.unpack_pairs(words, spec)
        assert np.array_equal(np.asarray(u1), r1)
        assert np.array_equal(np.asarray(u2), r2)
        # sorting by the packed words == sorting by (r1, r2)
        perm = lax.sort(
            (*words, jnp.arange(512, dtype=jnp.int32)),
            num_keys=spec.words, is_stable=True,
        )[-1]
        want = np.lexsort((np.arange(512), r2, r1))
        assert np.array_equal(np.asarray(perm), want)

    def test_overflow_rank_sorts_first(self):
        """OVERFLOW_RANK (-1) must pack below every real rank2 (the
        shorter-suffix-sorts-first rule survives packing)."""
        for n in (100, 100000):
            spec = keypack.pair_spec(n)
            r1 = jnp.asarray([5, 5, 5], jnp.int32)
            r2 = jnp.asarray([0, OVERFLOW_RANK, n - 1], jnp.int32)
            words = keypack.pack_pairs(r1, r2, spec)
            perm = lax.sort(
                (*words, jnp.arange(3, dtype=jnp.int32)), num_keys=spec.words
            )[-1]
            assert list(np.asarray(perm)) == [1, 0, 2], n

    @pytest.mark.parametrize("n", [2, 1000, 65535, 100000])
    def test_pads_sort_after_real_keys_unsigned(self, n):
        """Regression for the INT_PAD signed-compare bug: fused keys use the
        full uint32 range, so the pad must win an UNSIGNED comparison.  At
        n=65535 the packed field is exactly 32 bits and real keys exceed
        2^31 — int32 ordering would put them before small keys and the old
        INT_PAD (2^31 - 1) would sort before them entirely."""
        spec = keypack.pair_spec(n)
        pads = spec.pad_words()
        r1 = jnp.asarray([0, n - 1], jnp.int32)
        r2 = jnp.asarray([OVERFLOW_RANK, n - 1], jnp.int32)
        words = keypack.pack_pairs(r1, r2, spec)
        for w, p in zip(words, pads):
            assert w.dtype == jnp.uint32
            assert int(jnp.max(w)) < p  # strict: pads sort last
        if n == 65535:
            assert sum(spec.key_bits) == 32
            assert int(jnp.max(words[0])) > 2**31  # breaks signed compare
            assert pads[0] > jnp.iinfo(jnp.int32).max  # INT_PAD would lose

    def test_qgram_saturated_key_unsigned(self):
        """A text of all max-chars saturates the q-gram field (all-ones
        uint32); unsigned order must still rank it above smaller keys."""
        q, fpw, bits = keypack.qgram_params(16, 1)  # 4-bit chars, 8/word
        assert fpw * bits == 32
        hi = jnp.full(40, 15, jnp.int32)   # packs to 0xFFFFFFFF
        lo = jnp.full(40, 1, jnp.int32)
        (vh,) = keypack.qgram_keys_local(hi, fpw, bits, 1)
        (vl,) = keypack.qgram_keys_local(lo, fpw, bits, 1)
        assert int(vh[0]) == 0xFFFFFFFF
        assert bool(jnp.all(vh[: 40 - fpw] > vl[: 40 - fpw]))


class TestFastBuildParity:
    @pytest.mark.parametrize("sigma_hi", SIGMAS)
    @pytest.mark.parametrize("engine", ENGINES)
    def test_knob_matrix(self, sigma_hi, engine):
        """Fused/radix/q-gram/discard builds == the seed oracle, on odd
        (non-power-of-two) lengths."""
        n = 777  # deliberately odd
        s = _corpus(sigma_hi, n)
        sigma = al.sigma_of(s)
        want = np.asarray(isa_prefix_doubling(jnp.asarray(s), sigma))
        for qgram, qw in ((False, 1), (True, 1), (True, 2)):
            for discard in (False, True):
                got, stats = build_isa_fast(
                    jnp.asarray(s), sigma, local_sort=engine,
                    qgram=qgram, qgram_words=qw, discard=discard,
                )
                key = (sigma_hi, engine, qgram, qw, discard)
                assert np.array_equal(np.asarray(got), want), key
                assert stats.rounds_skipped == (
                    keypack.qgram_rounds_skipped(stats.q) if qgram else 0
                )

    def test_bwt_parity_downstream(self):
        """SA -> BWT equality against the naive oracle for the default
        fast configuration."""
        for sigma_hi in (4, 20):
            s = _corpus(sigma_hi, 1001, seed=7)
            sigma = al.sigma_of(s)
            sa, _ = suffix_array_fast(jnp.asarray(s), sigma)
            bwt_arr, row = bwt_from_sa(jnp.asarray(s), sa)
            want_bwt, want_row = bwt_naive(s)
            assert np.array_equal(np.asarray(bwt_arr), want_bwt)
            assert int(row) == want_row

    def test_rounds_and_active_shrink(self):
        """Discarding must shrink the active set monotonically and the
        q-gram init must skip >= 3 doubling rounds on a DNA-like corpus."""
        from repro.data.corpus import corpus

        s = al.append_sentinel(corpus("dna", 4095))
        sigma = al.sigma_of(s)
        isa, stats = build_isa_fast(jnp.asarray(s), sigma)
        assert np.array_equal(
            np.asarray(isa),
            np.asarray(isa_prefix_doubling(jnp.asarray(s), sigma)),
        )
        assert stats.rounds_skipped >= 3
        fr = stats.active_frac
        assert all(a >= b for a, b in zip(fr, fr[1:]))

    def test_count_locate_downstream(self):
        """build_index(fast=True) must serve identical count()/locate()
        to build_index(fast=False) (the seed builder)."""
        from repro.core.fm_index import PAD, count_naive
        from repro.core.pipeline import build_index

        rng = np.random.default_rng(3)
        toks = rng.integers(1, 5, 701).astype(np.int32)
        fast = build_index(toks, sample_rate=8, sa_sample_rate=8)
        slow = build_index(toks, sample_rate=8, sa_sample_rate=8, fast=False)
        assert fast.build_stats is not None and slow.build_stats is None
        B, L = 12, 5
        pats = np.full((B, L), PAD, np.int32)
        lens = rng.integers(1, L + 1, B)
        for b in range(B):
            pats[b, : lens[b]] = rng.integers(1, 5, lens[b])
        got = np.asarray(fast.count(pats))
        assert np.array_equal(got, np.asarray(slow.count(pats)))
        s = al.append_sentinel(toks)
        want = np.array([count_naive(s, pats[b, : lens[b]]) for b in range(B)])
        assert np.array_equal(got, want)
        fp, fc = fast.locate(pats, k=8)
        sp, sc = slow.locate(pats, k=8)
        assert np.array_equal(np.asarray(fp), np.asarray(sp))
        assert np.array_equal(np.asarray(fc), np.asarray(sc))
