"""Async serving frontend + FMQueryServer edge cases: empty flush,
oversize queries, admission-control shedding, drain-on-stop, per-bucket
metrics — and the stacked segment-parallel fan-out's bit-identity with the
sequential path (including across a compact() boundary)."""

import threading
import time

import numpy as np
import pytest

from repro.core.fm_index import PAD, count_naive
from repro.core.pipeline import build_index
from repro.core.segments import SegmentedIndex
from repro.serving.engine import FMQueryServer
from repro.serving.frontend import (
    AsyncQueryFrontend,
    DeadlineExceeded,
    Rejected,
)
from repro.testing import faultinject
from repro.testing.faultinject import FaultSchedule, InjectedFault

SIGMA = 5  # dna-like: tokens 1..4


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(7)
    toks = rng.integers(1, SIGMA, 2000).astype(np.int32)
    index = build_index(toks, sample_rate=16, sa_sample_rate=8)
    return rng, toks, index


def _server(index, **kw):
    kw.setdefault("length_buckets", (4, 8))
    kw.setdefault("max_batch", 16)
    kw.setdefault("locate_k", 4)
    return FMQueryServer(index, **kw)


class TestServerEdges:
    def test_empty_flush(self, built):
        _, _, index = built
        server = _server(index)
        assert server.flush() == {}
        assert server.stats.queries == 0 and server.stats.batches == 0

    def test_query_longer_than_any_bucket(self, built):
        """Oversize patterns escalate to the next pow2 bucket instead of
        truncating — the answer must equal the naive oracle."""
        _, toks, index = built
        server = _server(index)
        pat = toks[100:125]  # length 25 > largest bucket 8 -> bucket 32
        assert server._bucket_len(len(pat)) == 32
        got = server.count([pat])
        assert got[0] == count_naive(toks, pat)

    def test_flush_clears_queue_and_records_completed(self, built):
        _, toks, index = built
        server = _server(index)
        t = server.submit(toks[10:14])
        res = server.flush()
        assert server.flush() == {}  # queue drained by the first flush
        assert server.completed[t].count == res[t].count


class TestFrontend:
    def test_mixed_results_match_direct(self, built):
        rng, toks, index = built
        server = _server(index)
        pats, kinds = [], []
        for _ in range(40):
            L = int(rng.integers(2, 9))
            st = int(rng.integers(0, len(toks) - L))
            pats.append(toks[st : st + L])
            kinds.append("locate" if rng.random() < 0.5 else "count")
        with AsyncQueryFrontend(server, max_queue=256,
                                max_wait_ms=1.0) as fe:
            futs = [fe.submit(p, kd, k=4 if kd == "locate" else None)
                    for p, kd in zip(pats, kinds)]
            results = [f.result(timeout=120) for f in futs]
        L = max(len(p) for p in pats)
        padded = np.full((len(pats), L), PAD, np.int32)
        for i, p in enumerate(pats):
            padded[i, : len(p)] = p
        counts = np.asarray(index.count(padded))
        pos, _ = index.locate(padded, 4)
        pos = np.asarray(pos)
        for i, (res, kind) in enumerate(zip(results, kinds)):
            assert not isinstance(res, Rejected)
            assert res.kind == kind
            if kind == "count":
                assert res.count == counts[i]
            else:
                assert res.count == min(counts[i], 4)
                assert np.array_equal(
                    np.asarray(res.positions), pos[i][: res.count]
                )

    def test_queue_full_rejection(self, built):
        """Submits beyond max_queue shed immediately with a Rejected
        result; admitted requests still resolve once the worker runs."""
        _, toks, index = built
        fe = AsyncQueryFrontend(_server(index), max_queue=3,
                                autostart=False)
        admitted = [fe.submit(toks[:4]) for _ in range(3)]
        shed = fe.submit(toks[:4])
        assert isinstance(shed.result(timeout=1), Rejected)
        assert shed.result().reason == "queue_full"
        assert fe.rejected == 1 and fe.admitted == 3
        fe.stop()  # drains inline (worker never started)
        assert all(f.result(timeout=1).count >= 0 for f in admitted)
        m = fe.metrics()
        assert m["shed_frac"] == pytest.approx(0.25)
        assert m["completed"] == 3
        # compaction telemetry rides along even on a monolithic index
        # (attribute-absent fallbacks): zero fallbacks, no reason, no counts
        assert m["compact_fallbacks"] == 0
        assert m["compact_last_fallback_reason"] is None
        assert m["compact_strategy_counts"] == {}

    def test_burst_sheds_without_crashing(self, built):
        """Open-loop burst far above capacity: some requests shed, every
        admitted one answers correctly, nothing deadlocks."""
        rng, toks, index = built
        expect = {}
        with AsyncQueryFrontend(_server(index), max_queue=8,
                                max_wait_ms=0.5) as fe:
            futs = []
            for i in range(200):
                L = int(rng.integers(2, 9))
                st = int(rng.integers(0, len(toks) - L))
                expect[i] = count_naive(toks, toks[st : st + L])
                futs.append(fe.submit(toks[st : st + L]))
            results = [f.result(timeout=120) for f in futs]
        shed = sum(isinstance(r, Rejected) for r in results)
        assert shed > 0, "burst into a depth-8 queue should shed"
        for i, r in enumerate(results):
            if not isinstance(r, Rejected):
                assert r.count == expect[i]
        m = fe.metrics()
        assert m["rejected"] == shed
        assert m["admitted"] == 200 - shed == m["completed"]

    def test_metrics_buckets_have_percentiles(self, built):
        _, toks, index = built
        slo = {"count": 1e9, "locate": 1e9}
        with AsyncQueryFrontend(_server(index), max_queue=64,
                                slo_p99_ms=slo) as fe:
            futs = [fe.submit(toks[i : i + 3]) for i in range(10)]
            futs += [fe.submit(toks[i : i + 6], "locate") for i in range(5)]
            for f in futs:
                f.result(timeout=120)
            m = fe.metrics()
        assert set(m["buckets"]) == {"count/4", "locate/8"}
        b = m["buckets"]["count/4"]
        assert b["completed"] == 10
        assert 0 < b["p50_ms"] <= b["p99_ms"]
        assert b["slo_ok"] is True and b["violations"] == 0

    def test_slo_violations_counted(self, built):
        _, toks, index = built
        with AsyncQueryFrontend(_server(index), max_queue=64,
                                slo_p99_ms={"count": 1e-6}) as fe:
            fe.submit(toks[:4]).result(timeout=120)
            m = fe.metrics()
        b = m["buckets"]["count/4"]
        assert b["violations"] == 1 and b["slo_ok"] is False

    def test_worker_survives_dispatch_failure(self, built):
        """A request the server cannot answer resolves its future to the
        exception — the worker stays alive and keeps serving."""
        _, toks, index = built
        with AsyncQueryFrontend(_server(index), max_queue=16) as fe:
            bad = fe.submit(toks[:4], "locate", k=-1)  # invalid locate k
            with pytest.raises(Exception):
                bad.result(timeout=120)
            ok = fe.submit(toks[10:14])  # worker must still be alive
            assert ok.result(timeout=120).count >= 0

    def test_cancelled_future_does_not_wedge_worker(self, built):
        """A client cancelling a queued request must not kill the flush
        worker: later requests still resolve."""
        _, toks, index = built
        fe = AsyncQueryFrontend(_server(index), max_queue=16,
                                autostart=False)
        doomed = fe.submit(toks[:4])
        survivor = fe.submit(toks[10:14])
        assert doomed.cancel()  # still queued: cancellable
        fe.start()
        assert survivor.result(timeout=120).count >= 0
        with fe:  # frontend still alive and serving
            assert fe.submit(toks[:4]).result(timeout=120).count >= 0
        assert doomed.cancelled()

    def test_submit_after_stop_raises(self, built):
        _, toks, index = built
        fe = AsyncQueryFrontend(_server(index), max_queue=4)
        fe.stop()
        with pytest.raises(RuntimeError):
            fe.submit(toks[:4])

    def test_coalescing_batches_concurrent_producers(self, built):
        """Many producer threads, one flush worker: far fewer flushes than
        requests (max-wait coalescing), every result correct."""
        _, toks, index = built
        with AsyncQueryFrontend(_server(index), max_queue=1024,
                                max_wait_ms=20.0) as fe:
            futs, lock = [], threading.Lock()

            def produce():
                for _ in range(25):
                    f = fe.submit(toks[20:24])
                    with lock:
                        futs.append(f)
                    time.sleep(0.001)

            threads = [threading.Thread(target=produce) for _ in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            want = count_naive(toks, toks[20:24])
            assert all(f.result(timeout=120).count == want for f in futs)
            m = fe.metrics()
        assert m["flushes"] < m["completed"]


class TestFrontendFaults:
    """The self-healing layer: worker watchdog, per-query deadlines,
    growth-op retries, poison-op quarantine, and the close() guarantee
    that admitted futures always resolve."""

    def test_worker_crash_restarts_and_fails_only_inflight(self, built):
        """An injected ``worker.flush`` crash kills the worker thread; the
        watchdog fails that flush's futures with the crash exception,
        respawns a worker, and everything else answers exactly."""
        rng, toks, index = built
        expect = {}
        with faultinject.inject(FaultSchedule([("worker.flush", 0)])):
            with AsyncQueryFrontend(_server(index), max_queue=256,
                                    max_wait_ms=5.0) as fe:
                futs = []
                for i in range(30):
                    L = int(rng.integers(2, 9))
                    st = int(rng.integers(0, len(toks) - L))
                    expect[i] = count_naive(toks, toks[st : st + L])
                    futs.append(fe.submit(toks[st : st + L]))
                crashed = answered = 0
                for i, f in enumerate(futs):
                    try:
                        r = f.result(timeout=120)
                    except InjectedFault:
                        crashed += 1
                        continue
                    assert r.count == expect[i], i
                    answered += 1
                m = fe.metrics()
        assert crashed >= 1, "the injected crash hit no flush"
        assert answered == 30 - crashed
        assert m["worker_restarts"] == 1
        assert m["completed"] == answered

    def test_deadline_exceeded_resolves_instead_of_waiting(self, built):
        """A queued request whose deadline passes before its flush
        dispatches resolves to DeadlineExceeded — never hangs."""
        _, toks, index = built
        fe = AsyncQueryFrontend(_server(index), max_queue=16,
                                autostart=False)
        doomed = fe.submit(toks[:4], deadline_ms=0.0)
        alive = fe.submit(toks[:4], deadline_ms=60_000.0)
        time.sleep(0.005)  # let the zero deadline lapse while queued
        fe.start()
        assert isinstance(doomed.result(timeout=120), DeadlineExceeded)
        assert doomed.result().kind == "count"
        assert alive.result(timeout=120).count == count_naive(toks, toks[:4])
        fe.stop()
        m = fe.metrics()
        assert m["deadline_exceeded"] == 1 and m["completed"] == 1

    def test_negative_deadline_rejected_at_submit(self, built):
        _, toks, index = built
        fe = AsyncQueryFrontend(_server(index), max_queue=4, autostart=False)
        with pytest.raises(ValueError, match="deadline_ms"):
            fe.submit(toks[:4], deadline_ms=-1.0)
        fe.stop()

    def test_transient_compaction_fault_retried(self):
        """One injected merge crash during the growth op's compaction:
        the capped-backoff retry succeeds, nothing quarantines."""
        rng = np.random.default_rng(23)
        # force the pairwise merge: the cost model would route this tiny
        # run to the rebuild, and merge.mid only fires on the merge walk
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             segment_min_tokens=1 << 10,
                             compact_strategy="pairwise")
        seg.append(rng.integers(1, SIGMA, 300).astype(np.int32))
        new = rng.integers(1, SIGMA, 120).astype(np.int32)
        with faultinject.inject(FaultSchedule([("merge.mid", 0)])):
            with AsyncQueryFrontend(_server(seg), max_queue=16,
                                    growth_backoff_ms=1.0) as fe:
                info = fe.append(new).result(timeout=120)
                m = fe.metrics()
        assert info["merges"] == 1 and info["segments"] == 1
        assert not info["compaction_quarantined"]
        assert m["retries"] == 1 and m["quarantined_segments"] == 0
        assert not m["degraded"]

    def test_poison_compaction_quarantined_pre_compact_serves(self):
        """A compaction that fails every retry is quarantined: the append
        itself still lands, the pre-compact segments keep serving exactly,
        later appends skip compaction, and resume_compaction() recovers."""
        rng = np.random.default_rng(24)
        # forced pairwise for the same reason as above: the armed
        # merge.mid poison must sit on the executed compaction path
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             segment_min_tokens=1 << 10,
                             compact_strategy="pairwise")
        first = rng.integers(1, SIGMA, 300).astype(np.int32)
        seg.append(first)
        new = rng.integers(1, SIGMA, 120).astype(np.int32)
        # retries=3 -> exactly 4 attempts; arm a crash for each
        poison = FaultSchedule([("merge.mid", k) for k in range(4)])
        with faultinject.inject(poison):
            with AsyncQueryFrontend(_server(seg), max_queue=16,
                                    growth_backoff_ms=1.0) as fe:
                info = fe.append(new).result(timeout=120)
                assert info["appended"] == 120 and info["merges"] == 0
                assert info["compaction_quarantined"]
                assert "compaction_error" in info
                # pre-compact generation serves: both texts answer exactly
                got_old = fe.submit(first[5:11]).result(timeout=120)
                got_new = fe.submit(new[50:56]).result(timeout=120)
                assert got_old.count >= 1
                assert got_new.count >= 1
                # the quarantine sticks: this append must NOT re-attempt
                # compaction (no armed trigger left would stop it anyway)
                info2 = fe.append(
                    rng.integers(1, SIGMA, 50).astype(np.int32)
                ).result(timeout=120)
                assert info2["merges"] == 0
                assert info2["compaction_quarantined"]
                m = fe.metrics()
                assert m["quarantined_segments"] == 1
                assert m["degraded"] and m["retries"] == 3
                # operator fixed the cause: compaction resumes and merges
                # the whole backlog of small segments
                fe.resume_compaction()
                info3 = fe.append(
                    rng.integers(1, SIGMA, 50).astype(np.int32)
                ).result(timeout=120)
                assert info3["merges"] == 1 and info3["segments"] == 1
                assert not info3["compaction_quarantined"]
                assert not fe.metrics()["degraded"]
        assert len(seg.segments) == 1

    def test_submit_then_immediate_close_resolves_everything(self, built):
        """Regression: close() right after a burst of submits must resolve
        every admitted future (drain), not leave callers hanging."""
        _, toks, index = built
        want = count_naive(toks, toks[20:24])
        for trial in range(5):  # race close() against the worker repeatedly
            fe = AsyncQueryFrontend(_server(index), max_queue=256,
                                    max_wait_ms=50.0)
            futs = [fe.submit(toks[20:24]) for _ in range(8)]
            fe.close()
            for f in futs:
                assert f.result(timeout=30).count == want, trial
            with pytest.raises(RuntimeError):
                fe.submit(toks[:4])

    def test_close_after_worker_crash_still_resolves(self, built):
        """Even when the worker crashes on every flush it attempts, close()
        resolves the leftovers inline (exception or Shutdown, never a
        hang)."""
        _, toks, index = built
        with faultinject.inject(FaultSchedule([("worker.flush", 0)])):
            fe = AsyncQueryFrontend(_server(index), max_queue=64,
                                    max_wait_ms=200.0)
            futs = [fe.submit(toks[20:24]) for _ in range(6)]
            fe.close()
            outcomes = []
            for f in futs:
                try:
                    outcomes.append(f.result(timeout=30))
                except InjectedFault:
                    outcomes.append("crashed")
            assert len(outcomes) == 6  # nothing hung
        assert fe.metrics()["worker_restarts"] <= 1


class TestSegmentParallelParity:
    """The stacked fan-out must be bit-identical to the sequential loop —
    including across a compact() boundary (stacked layout rebuilt) and when
    served through the query server."""

    @pytest.fixture(scope="class")
    def seg_built(self):
        rng = np.random.default_rng(11)
        chunks = [rng.integers(1, SIGMA, n).astype(np.int32)
                  for n in (350, 120, 60, 500, 90)]
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        for c in chunks:
            seg.append(c)
        full = np.concatenate(chunks)
        pats = np.full((20, 6), PAD, np.int32)
        for b in range(20):
            L = int(rng.integers(1, 7))
            st = int(rng.integers(0, len(full) - L))
            pats[b, :L] = full[st : st + L]
        return seg, pats

    def _both(self, seg, fn):
        seg.parallel, seg._stacked_cache = True, None
        par = fn()
        assert seg._stacked_cache not in (None, False), "stacked path unused"
        seg.parallel, seg._stacked_cache = False, None
        sequ = fn()
        seg.parallel = None
        return par, sequ

    def test_count_parity(self, seg_built):
        seg, pats = seg_built
        par, sequ = self._both(seg, lambda: seg.count(pats))
        assert np.array_equal(par, sequ)

    def test_locate_parity(self, seg_built):
        seg, pats = seg_built
        (pp, pc), (sp, sc) = self._both(seg, lambda: seg.locate(pats, 4))
        assert np.array_equal(pp, sp) and np.array_equal(pc, sc)

    def test_parity_across_compact_boundary(self, seg_built):
        """compact() merges runs of small segments — the rebuilt stacked
        layout must still match the sequential answers exactly."""
        seg, pats = seg_built
        before = seg.count(pats)
        assert seg.compact(min_tokens=200) >= 1
        par, sequ = self._both(seg, lambda: seg.count(pats))
        assert np.array_equal(par, sequ)
        # compaction can only reveal former cross-boundary matches
        assert (par >= before).all()
        (pp, pc), (sp, sc) = self._both(seg, lambda: seg.locate(pats, 4))
        assert np.array_equal(pp, sp) and np.array_equal(pc, sc)

    def test_served_identically_through_frontend(self, seg_built):
        seg, pats = seg_built
        seg.parallel = True
        server = _server(seg)
        with AsyncQueryFrontend(server, max_queue=64) as fe:
            futs = [fe.submit(pats[b][pats[b] != PAD]) for b in range(20)]
            got = np.array([f.result(timeout=120).count for f in futs])
        seg.parallel = None
        assert np.array_equal(got, seg.count(pats))

    def test_single_segment_auto_stays_sequential(self):
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        seg.append(np.ones(50, np.int32))
        assert seg._stacked() is None  # auto: no stacking for one segment
        seg.parallel = True
        assert seg._stacked() is not None  # forced: stack of one works


class TestFrontendAppend:
    """Live index growth through the async frontend: appends apply between
    flushes on the worker thread, trigger the background merge-compaction
    policy, and queries spanning old and appended segments answer exactly."""

    def _segmented(self, rng, n=600):
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             segment_min_tokens=1 << 10,
                             compact_trigger_ratio=0.5)
        seg.append(rng.integers(1, SIGMA, n).astype(np.int32))
        return seg

    def test_append_grows_index_and_compacts(self):
        rng = np.random.default_rng(17)
        seg = self._segmented(rng)
        old = seg.segments[0].tokens
        new = rng.integers(1, SIGMA, 200).astype(np.int32)
        with AsyncQueryFrontend(_server(seg), max_queue=64) as fe:
            before = fe.submit(old[5:10]).result(timeout=120)
            info = fe.append(new).result(timeout=120)
            # policy: 2/2 segments small -> merge compaction fires
            assert info["appended"] == 200 and info["merges"] == 1
            assert info["segments"] == 1
            assert info["total_tokens"] == len(old) + 200
            after_old = fe.submit(old[5:10]).result(timeout=120)
            after_new = fe.submit(new[50:55]).result(timeout=120)
            m = fe.metrics()
        assert after_old.count == before.count  # compaction is invariant
        full_docs = [old, new]
        want = sum(count_naive(d, new[50:55]) for d in full_docs)
        assert after_new.count == want and want >= 1
        assert m["appends"] == 1 and m["compactions"] == 1

    def test_append_rejected_for_monolithic_index(self, built):
        _, toks, index = built
        with AsyncQueryFrontend(_server(index), max_queue=8) as fe:
            with pytest.raises(TypeError, match="append"):
                fe.append(toks[:16])

    def test_append_error_resolves_future_and_worker_survives(self):
        rng = np.random.default_rng(18)
        seg = self._segmented(rng)
        with AsyncQueryFrontend(_server(seg), max_queue=8) as fe:
            bad = fe.append(np.array([99], np.int32))  # out of alphabet
            with pytest.raises(ValueError):
                bad.result(timeout=120)
            ok = fe.submit(seg.segments[0].tokens[:6])
            assert ok.result(timeout=120).count >= 1

    def test_serve_launcher_append_flow(self, tmp_path):
        """launch.serve end-to-end: build+save a segmented catalog, then
        restore + --append + --serve-async; the appended text must be
        queryable and the re-saved catalog must contain it."""
        from repro.launch import serve as serve_launcher

        ckpt = str(tmp_path / "cat")
        extra_path = str(tmp_path / "extra.npy")
        rng = np.random.default_rng(3)
        np.save(extra_path, rng.integers(1, 5, 512).astype(np.int32))
        serve_launcher.main([
            "--kind", "dna", "--n", "2048", "--segments", "2",
            "--batch", "4", "--batches", "2", "--ckpt-dir", ckpt,
        ])
        serve_launcher.main([
            "--restore", "--ckpt-dir", ckpt, "--append", extra_path,
            "--serve-async", "--batch", "4", "--batches", "2",
            "--queue-depth", "128",
        ])
        reloaded = SegmentedIndex.load(ckpt)
        assert reloaded.total_tokens == 2048 + 512
        extra = np.load(extra_path)
        want = count_naive(extra, extra[100:110])
        got = reloaded.count(
            np.asarray(extra[100:110], np.int32)[None, :]
        )[0]
        assert got >= want >= 1
