"""Async serving frontend + FMQueryServer edge cases: empty flush,
oversize queries, admission-control shedding, drain-on-stop, per-bucket
metrics — and the stacked segment-parallel fan-out's bit-identity with the
sequential path (including across a compact() boundary)."""

import threading
import time

import numpy as np
import pytest

from repro.core.fm_index import PAD, count_naive
from repro.core.pipeline import build_index
from repro.core.segments import SegmentedIndex
from repro.serving.engine import FMQueryServer
from repro.serving.frontend import AsyncQueryFrontend, Rejected

SIGMA = 5  # dna-like: tokens 1..4


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(7)
    toks = rng.integers(1, SIGMA, 2000).astype(np.int32)
    index = build_index(toks, sample_rate=16, sa_sample_rate=8)
    return rng, toks, index


def _server(index, **kw):
    kw.setdefault("length_buckets", (4, 8))
    kw.setdefault("max_batch", 16)
    kw.setdefault("locate_k", 4)
    return FMQueryServer(index, **kw)


class TestServerEdges:
    def test_empty_flush(self, built):
        _, _, index = built
        server = _server(index)
        assert server.flush() == {}
        assert server.stats.queries == 0 and server.stats.batches == 0

    def test_query_longer_than_any_bucket(self, built):
        """Oversize patterns escalate to the next pow2 bucket instead of
        truncating — the answer must equal the naive oracle."""
        _, toks, index = built
        server = _server(index)
        pat = toks[100:125]  # length 25 > largest bucket 8 -> bucket 32
        assert server._bucket_len(len(pat)) == 32
        got = server.count([pat])
        assert got[0] == count_naive(toks, pat)

    def test_flush_clears_queue_and_records_completed(self, built):
        _, toks, index = built
        server = _server(index)
        t = server.submit(toks[10:14])
        res = server.flush()
        assert server.flush() == {}  # queue drained by the first flush
        assert server.completed[t].count == res[t].count


class TestFrontend:
    def test_mixed_results_match_direct(self, built):
        rng, toks, index = built
        server = _server(index)
        pats, kinds = [], []
        for _ in range(40):
            L = int(rng.integers(2, 9))
            st = int(rng.integers(0, len(toks) - L))
            pats.append(toks[st : st + L])
            kinds.append("locate" if rng.random() < 0.5 else "count")
        with AsyncQueryFrontend(server, max_queue=256,
                                max_wait_ms=1.0) as fe:
            futs = [fe.submit(p, kd, k=4 if kd == "locate" else None)
                    for p, kd in zip(pats, kinds)]
            results = [f.result(timeout=120) for f in futs]
        L = max(len(p) for p in pats)
        padded = np.full((len(pats), L), PAD, np.int32)
        for i, p in enumerate(pats):
            padded[i, : len(p)] = p
        counts = np.asarray(index.count(padded))
        pos, _ = index.locate(padded, 4)
        pos = np.asarray(pos)
        for i, (res, kind) in enumerate(zip(results, kinds)):
            assert not isinstance(res, Rejected)
            assert res.kind == kind
            if kind == "count":
                assert res.count == counts[i]
            else:
                assert res.count == min(counts[i], 4)
                assert np.array_equal(
                    np.asarray(res.positions), pos[i][: res.count]
                )

    def test_queue_full_rejection(self, built):
        """Submits beyond max_queue shed immediately with a Rejected
        result; admitted requests still resolve once the worker runs."""
        _, toks, index = built
        fe = AsyncQueryFrontend(_server(index), max_queue=3,
                                autostart=False)
        admitted = [fe.submit(toks[:4]) for _ in range(3)]
        shed = fe.submit(toks[:4])
        assert isinstance(shed.result(timeout=1), Rejected)
        assert shed.result().reason == "queue_full"
        assert fe.rejected == 1 and fe.admitted == 3
        fe.stop()  # drains inline (worker never started)
        assert all(f.result(timeout=1).count >= 0 for f in admitted)
        m = fe.metrics()
        assert m["shed_frac"] == pytest.approx(0.25)
        assert m["completed"] == 3

    def test_burst_sheds_without_crashing(self, built):
        """Open-loop burst far above capacity: some requests shed, every
        admitted one answers correctly, nothing deadlocks."""
        rng, toks, index = built
        expect = {}
        with AsyncQueryFrontend(_server(index), max_queue=8,
                                max_wait_ms=0.5) as fe:
            futs = []
            for i in range(200):
                L = int(rng.integers(2, 9))
                st = int(rng.integers(0, len(toks) - L))
                expect[i] = count_naive(toks, toks[st : st + L])
                futs.append(fe.submit(toks[st : st + L]))
            results = [f.result(timeout=120) for f in futs]
        shed = sum(isinstance(r, Rejected) for r in results)
        assert shed > 0, "burst into a depth-8 queue should shed"
        for i, r in enumerate(results):
            if not isinstance(r, Rejected):
                assert r.count == expect[i]
        m = fe.metrics()
        assert m["rejected"] == shed
        assert m["admitted"] == 200 - shed == m["completed"]

    def test_metrics_buckets_have_percentiles(self, built):
        _, toks, index = built
        slo = {"count": 1e9, "locate": 1e9}
        with AsyncQueryFrontend(_server(index), max_queue=64,
                                slo_p99_ms=slo) as fe:
            futs = [fe.submit(toks[i : i + 3]) for i in range(10)]
            futs += [fe.submit(toks[i : i + 6], "locate") for i in range(5)]
            for f in futs:
                f.result(timeout=120)
            m = fe.metrics()
        assert set(m["buckets"]) == {"count/4", "locate/8"}
        b = m["buckets"]["count/4"]
        assert b["completed"] == 10
        assert 0 < b["p50_ms"] <= b["p99_ms"]
        assert b["slo_ok"] is True and b["violations"] == 0

    def test_slo_violations_counted(self, built):
        _, toks, index = built
        with AsyncQueryFrontend(_server(index), max_queue=64,
                                slo_p99_ms={"count": 1e-6}) as fe:
            fe.submit(toks[:4]).result(timeout=120)
            m = fe.metrics()
        b = m["buckets"]["count/4"]
        assert b["violations"] == 1 and b["slo_ok"] is False

    def test_worker_survives_dispatch_failure(self, built):
        """A request the server cannot answer resolves its future to the
        exception — the worker stays alive and keeps serving."""
        _, toks, index = built
        with AsyncQueryFrontend(_server(index), max_queue=16) as fe:
            bad = fe.submit(toks[:4], "locate", k=-1)  # invalid locate k
            with pytest.raises(Exception):
                bad.result(timeout=120)
            ok = fe.submit(toks[10:14])  # worker must still be alive
            assert ok.result(timeout=120).count >= 0

    def test_cancelled_future_does_not_wedge_worker(self, built):
        """A client cancelling a queued request must not kill the flush
        worker: later requests still resolve."""
        _, toks, index = built
        fe = AsyncQueryFrontend(_server(index), max_queue=16,
                                autostart=False)
        doomed = fe.submit(toks[:4])
        survivor = fe.submit(toks[10:14])
        assert doomed.cancel()  # still queued: cancellable
        fe.start()
        assert survivor.result(timeout=120).count >= 0
        with fe:  # frontend still alive and serving
            assert fe.submit(toks[:4]).result(timeout=120).count >= 0
        assert doomed.cancelled()

    def test_submit_after_stop_raises(self, built):
        _, toks, index = built
        fe = AsyncQueryFrontend(_server(index), max_queue=4)
        fe.stop()
        with pytest.raises(RuntimeError):
            fe.submit(toks[:4])

    def test_coalescing_batches_concurrent_producers(self, built):
        """Many producer threads, one flush worker: far fewer flushes than
        requests (max-wait coalescing), every result correct."""
        _, toks, index = built
        with AsyncQueryFrontend(_server(index), max_queue=1024,
                                max_wait_ms=20.0) as fe:
            futs, lock = [], threading.Lock()

            def produce():
                for _ in range(25):
                    f = fe.submit(toks[20:24])
                    with lock:
                        futs.append(f)
                    time.sleep(0.001)

            threads = [threading.Thread(target=produce) for _ in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            want = count_naive(toks, toks[20:24])
            assert all(f.result(timeout=120).count == want for f in futs)
            m = fe.metrics()
        assert m["flushes"] < m["completed"]


class TestSegmentParallelParity:
    """The stacked fan-out must be bit-identical to the sequential loop —
    including across a compact() boundary (stacked layout rebuilt) and when
    served through the query server."""

    @pytest.fixture(scope="class")
    def seg_built(self):
        rng = np.random.default_rng(11)
        chunks = [rng.integers(1, SIGMA, n).astype(np.int32)
                  for n in (350, 120, 60, 500, 90)]
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        for c in chunks:
            seg.append(c)
        full = np.concatenate(chunks)
        pats = np.full((20, 6), PAD, np.int32)
        for b in range(20):
            L = int(rng.integers(1, 7))
            st = int(rng.integers(0, len(full) - L))
            pats[b, :L] = full[st : st + L]
        return seg, pats

    def _both(self, seg, fn):
        seg.parallel, seg._stacked_cache = True, None
        par = fn()
        assert seg._stacked_cache not in (None, False), "stacked path unused"
        seg.parallel, seg._stacked_cache = False, None
        sequ = fn()
        seg.parallel = None
        return par, sequ

    def test_count_parity(self, seg_built):
        seg, pats = seg_built
        par, sequ = self._both(seg, lambda: seg.count(pats))
        assert np.array_equal(par, sequ)

    def test_locate_parity(self, seg_built):
        seg, pats = seg_built
        (pp, pc), (sp, sc) = self._both(seg, lambda: seg.locate(pats, 4))
        assert np.array_equal(pp, sp) and np.array_equal(pc, sc)

    def test_parity_across_compact_boundary(self, seg_built):
        """compact() merges runs of small segments — the rebuilt stacked
        layout must still match the sequential answers exactly."""
        seg, pats = seg_built
        before = seg.count(pats)
        assert seg.compact(min_tokens=200) >= 1
        par, sequ = self._both(seg, lambda: seg.count(pats))
        assert np.array_equal(par, sequ)
        # compaction can only reveal former cross-boundary matches
        assert (par >= before).all()
        (pp, pc), (sp, sc) = self._both(seg, lambda: seg.locate(pats, 4))
        assert np.array_equal(pp, sp) and np.array_equal(pc, sc)

    def test_served_identically_through_frontend(self, seg_built):
        seg, pats = seg_built
        seg.parallel = True
        server = _server(seg)
        with AsyncQueryFrontend(server, max_queue=64) as fe:
            futs = [fe.submit(pats[b][pats[b] != PAD]) for b in range(20)]
            got = np.array([f.result(timeout=120).count for f in futs])
        seg.parallel = None
        assert np.array_equal(got, seg.count(pats))

    def test_single_segment_auto_stays_sequential(self):
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        seg.append(np.ones(50, np.int32))
        assert seg._stacked() is None  # auto: no stacking for one segment
        seg.parallel = True
        assert seg._stacked() is not None  # forced: stack of one works


class TestFrontendAppend:
    """Live index growth through the async frontend: appends apply between
    flushes on the worker thread, trigger the background merge-compaction
    policy, and queries spanning old and appended segments answer exactly."""

    def _segmented(self, rng, n=600):
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             segment_min_tokens=1 << 10,
                             compact_trigger_ratio=0.5)
        seg.append(rng.integers(1, SIGMA, n).astype(np.int32))
        return seg

    def test_append_grows_index_and_compacts(self):
        rng = np.random.default_rng(17)
        seg = self._segmented(rng)
        old = seg.segments[0].tokens
        new = rng.integers(1, SIGMA, 200).astype(np.int32)
        with AsyncQueryFrontend(_server(seg), max_queue=64) as fe:
            before = fe.submit(old[5:10]).result(timeout=120)
            info = fe.append(new).result(timeout=120)
            # policy: 2/2 segments small -> merge compaction fires
            assert info["appended"] == 200 and info["merges"] == 1
            assert info["segments"] == 1
            assert info["total_tokens"] == len(old) + 200
            after_old = fe.submit(old[5:10]).result(timeout=120)
            after_new = fe.submit(new[50:55]).result(timeout=120)
            m = fe.metrics()
        assert after_old.count == before.count  # compaction is invariant
        full_docs = [old, new]
        want = sum(count_naive(d, new[50:55]) for d in full_docs)
        assert after_new.count == want and want >= 1
        assert m["appends"] == 1 and m["compactions"] == 1

    def test_append_rejected_for_monolithic_index(self, built):
        _, toks, index = built
        with AsyncQueryFrontend(_server(index), max_queue=8) as fe:
            with pytest.raises(TypeError, match="append"):
                fe.append(toks[:16])

    def test_append_error_resolves_future_and_worker_survives(self):
        rng = np.random.default_rng(18)
        seg = self._segmented(rng)
        with AsyncQueryFrontend(_server(seg), max_queue=8) as fe:
            bad = fe.append(np.array([99], np.int32))  # out of alphabet
            with pytest.raises(ValueError):
                bad.result(timeout=120)
            ok = fe.submit(seg.segments[0].tokens[:6])
            assert ok.result(timeout=120).count >= 1

    def test_serve_launcher_append_flow(self, tmp_path):
        """launch.serve end-to-end: build+save a segmented catalog, then
        restore + --append + --serve-async; the appended text must be
        queryable and the re-saved catalog must contain it."""
        from repro.launch import serve as serve_launcher

        ckpt = str(tmp_path / "cat")
        extra_path = str(tmp_path / "extra.npy")
        rng = np.random.default_rng(3)
        np.save(extra_path, rng.integers(1, 5, 512).astype(np.int32))
        serve_launcher.main([
            "--kind", "dna", "--n", "2048", "--segments", "2",
            "--batch", "4", "--batches", "2", "--ckpt-dir", ckpt,
        ])
        serve_launcher.main([
            "--restore", "--ckpt-dir", ckpt, "--append", extra_path,
            "--serve-async", "--batch", "4", "--batches", "2",
            "--queue-depth", "128",
        ])
        reloaded = SegmentedIndex.load(ckpt)
        assert reloaded.total_tokens == 2048 + 512
        extra = np.load(extra_path)
        want = count_naive(extra, extra[100:110])
        got = reloaded.count(
            np.asarray(extra[100:110], np.int32)[None, :]
        )[0]
        assert got >= want >= 1
