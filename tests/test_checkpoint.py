"""Fault-tolerance tests: atomic checkpointing, bitwise resume, keep-k GC,
async save, and elastic restore metadata."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_reduced_config
from repro.data.corpus import corpus
from repro.data.loader import LoaderConfig, TokenLoader
from repro.sharding import single_device_context
from repro.training.checkpoint import Checkpointer
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


@pytest.fixture(scope="module")
def ctx():
    return single_device_context()


class TestCheckpointer:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        tree = {"a": jnp.arange(10, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
        ck.save(7, tree, extra={"cursor": 42})
        restored, meta = ck.restore(tree)
        assert meta["step"] == 7 and meta["cursor"] == 42
        assert np.array_equal(np.asarray(restored["a"]), np.arange(10))
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_keep_k_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        tree = {"x": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            ck.save(s, tree)
        assert ck.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        tree = {"x": jnp.arange(100.0)}
        ck.save_async(3, tree)
        ck.wait()
        restored, meta = ck.restore(tree)
        assert meta["step"] == 3
        assert np.array_equal(np.asarray(restored["x"]), np.arange(100.0))

    def test_atomic_no_partial_dirs(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"x": jnp.zeros(2)})
        entries = os.listdir(tmp_path)
        assert entries == ["step_00000001"]  # no .tmp left behind

    def test_latest_of_empty(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        assert ck.latest_step() is None


class TestResume:
    def test_bitwise_resume(self, ctx, tmp_path):
        """Kill training at step 6, restart from the checkpoint, verify the
        loss trajectory is exactly the uninterrupted run's."""
        cfg = get_reduced_config("qwen2p5_3b").replace(vocab_size=128)
        toks = corpus("english", 8000) % 128
        loader = TokenLoader(toks, LoaderConfig(2, 16, seed=3))
        tcfg = TrainConfig(
            opt=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12),
            checkpoint_every=3, log_every=0,
        )

        full = train(cfg, ctx, tcfg, loader, 12, ckpt_dir=str(tmp_path / "a"),
                     seed=7, log=lambda *_: None)

        # interrupted run: first 6 steps, then resume to 12
        train(cfg, ctx, tcfg, loader, 6, ckpt_dir=str(tmp_path / "b"),
              seed=7, log=lambda *_: None)
        resumed = train(cfg, ctx, tcfg, loader, 12,
                        ckpt_dir=str(tmp_path / "b"), resume=True, seed=7,
                        log=lambda *_: None)

        np.testing.assert_array_equal(
            np.array(full["losses"][6:]), np.array(resumed["losses"])
        )

    def test_index_build_state_checkpoint(self, tmp_path):
        """The prefix-doubling loop state checkpoints and resumes (the
        paper's Spark lineage -> explicit state, DESIGN.md §7)."""
        from repro.core import alphabet as al
        from repro.core.suffix_array import (
            initial_ranks, rerank_from_sorted, shifted_ranks,
        )
        from jax import lax

        rng = np.random.default_rng(0)
        s = al.append_sentinel(rng.integers(1, 5, 63).astype(np.int32))
        sigma = al.sigma_of(s)
        sd = jnp.asarray(s)
        n = len(s)
        idx = jnp.arange(n, dtype=jnp.int32)

        def one_round(rank, h):
            r2 = shifted_ranks(rank, jnp.int32(h))
            r1s, r2s, perm = lax.sort((rank, r2, idx), num_keys=2)
            new_sorted, _ = rerank_from_sorted(r1s, r2s)
            return jnp.zeros_like(rank).at[perm].set(new_sorted)

        # run 3 rounds, checkpoint, restore, run to completion
        rank = initial_ranks(sd, sigma)
        h = 1
        for _ in range(3):
            rank = one_round(rank, h)
            h *= 2
        ck = Checkpointer(str(tmp_path))
        ck.save(3, {"rank": rank}, extra={"h": h})
        restored, meta = ck.restore({"rank": rank})
        rank2, h2 = restored["rank"], meta["h"]
        while h2 < n:
            rank2 = one_round(rank2, h2)
            h2 *= 2
        # reference: uninterrupted
        rank_ref = initial_ranks(sd, sigma)
        h = 1
        while h < n:
            rank_ref = one_round(rank_ref, h)
            h *= 2
        assert np.array_equal(np.asarray(rank2), np.asarray(rank_ref))
