"""Query-engine tests: packed-rank parity, locate vs the full-SA oracle,
and PAD / out-of-alphabet edge cases, across alphabet sizes and layouts.

The packed rank path has three implementations (Pallas kernel, its
interpret mode, and the jnp popcount fallback) plus a naive unpack-and-scan
oracle in kernels/ref.py; they must agree bit-for-bit on random batches.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import alphabet as al
from repro.core.bwt import bwt
from repro.core.fm_index import (
    PAD,
    build_fm_index,
    count,
    count_naive,
    locate,
    locate_naive,
)
from repro.core.suffix_array import suffix_array
from repro.kernels import ops, ref
from repro.kernels.rank_select import pack_words, packed_bits


def _fused_fixture(rng, bits, sigma, nblocks, r):
    """Random fused [checkpoint | packed words] array + raw symbols."""
    syms = rng.integers(0, sigma, nblocks * r).astype(np.int32)
    words = np.asarray(pack_words(jnp.asarray(syms), bits)).reshape(nblocks, -1)
    onehot = (syms.reshape(nblocks, r)[:, :, None] == np.arange(sigma)).sum(1)
    occ = np.concatenate(
        [np.zeros((1, sigma), np.int64), np.cumsum(onehot, 0)]
    )[:nblocks].astype(np.int32)
    return jnp.asarray(np.concatenate([occ, words], axis=1)), syms, occ


class TestPackedRankParity:
    @pytest.mark.parametrize("bits,sigma,r", [
        (2, 4, 16), (2, 3, 32), (4, 16, 64), (4, 5, 8), (4, 6, 64),
    ])
    def test_all_impls_match_truth(self, bits, sigma, r):
        rng = np.random.default_rng(bits * 100 + sigma + r)
        nblocks = 17
        fused, syms, occ = _fused_fixture(rng, bits, sigma, nblocks, r)
        B = 53  # deliberately not a multiple of queries_per_step
        bidx = jnp.asarray(rng.integers(0, nblocks, B).astype(np.int32))
        c = jnp.asarray(rng.integers(0, sigma, B).astype(np.int32))
        cut = jnp.asarray(rng.integers(0, r + 1, B).astype(np.int32))
        want = occ[np.asarray(bidx), np.asarray(c)] + np.array([
            (syms.reshape(nblocks, r)[b, :k] == ch).sum()
            for b, ch, k in zip(np.asarray(bidx), np.asarray(c),
                                np.asarray(cut))
        ])
        kw = dict(bits=bits, sigma=sigma)
        for impl in ("jnp", "interpret"):
            got = np.asarray(
                ops.rank_packed(fused, bidx, c, cut, impl=impl, **kw)
            )
            assert np.array_equal(got, want), impl
        got_ref = np.asarray(ref.rank_packed_ref(fused, bidx, c, cut, **kw))
        assert np.array_equal(got_ref, want)

    def test_packed_bits_selection(self):
        assert packed_bits(4, 16) == 2
        assert packed_bits(5, 64) == 4
        assert packed_bits(16, 64) == 4
        assert packed_bits(17, 64) == 0       # alphabet too large
        assert packed_bits(4, 4) == 0         # r not a multiple of fields/word
        assert packed_bits(5, 8) == 4

    def test_full_and_zero_cutoffs(self):
        rng = np.random.default_rng(0)
        fused, syms, occ = _fused_fixture(rng, 4, 7, 4, 8)
        bidx = jnp.asarray([0, 3], np.int32)
        c = jnp.asarray([2, 2], np.int32)
        for cutv in (0, 8):
            cut = jnp.full((2,), cutv, jnp.int32)
            got = np.asarray(ops.rank_packed(
                fused, bidx, c, cut, bits=4, sigma=7, impl="jnp"))
            want = occ[[0, 3], 2] + (
                syms.reshape(4, 8)[[0, 3], :cutv] == 2).sum(axis=1)
            assert np.array_equal(got, want), cutv


def _build(rng, sigma_hi, n, sample_rate, srate=8, pack=None):
    toks = rng.integers(1, max(2, sigma_hi), n).astype(np.int32)
    s = al.append_sentinel(toks)
    sigma = al.sigma_of(s)
    b, row = bwt(jnp.asarray(s), sigma)
    sa = suffix_array(jnp.asarray(s), sigma)
    fm = build_fm_index(b, row, sigma, sample_rate, sa=sa,
                        sa_sample_rate=srate, pack=pack)
    return fm, s, sa


class TestCountParityAcrossLayouts:
    @pytest.mark.parametrize("sigma_hi,sample_rate", [
        (2, 16),   # sigma 2 -> 2-bit
        (4, 32),   # sigma 4 or 5 -> 2/4-bit
        (16, 16),  # sigma up to 16 -> 4-bit
        (30, 16),  # sigma > 16 -> unpacked fallback
    ])
    def test_packed_equals_unpacked_equals_naive(self, sigma_hi, sample_rate):
        rng = np.random.default_rng(sigma_hi + sample_rate)
        fm, s, _sa = _build(rng, sigma_hi, 400, sample_rate)
        fm_ref, _, _ = _build(
            np.random.default_rng(sigma_hi + sample_rate), sigma_hi, 400,
            sample_rate, pack=False,
        )
        B, L = 20, 6
        pats = np.full((B, L), PAD, np.int32)
        lens = rng.integers(1, L + 1, B)
        for i, m in enumerate(lens):
            pats[i, :m] = rng.integers(1, max(2, sigma_hi), m)
        got = np.asarray(count(fm, jnp.asarray(pats)))
        got_ref = np.asarray(count(fm_ref, jnp.asarray(pats)))
        want = [count_naive(s, pats[i, :lens[i]]) for i in range(B)]
        assert list(got) == want
        assert list(got_ref) == want


class TestLocate:
    @pytest.mark.parametrize("sigma_hi", [2, 4, 16])
    @pytest.mark.parametrize("srate", [4, 16])
    def test_matches_full_sa_oracle(self, sigma_hi, srate):
        rng = np.random.default_rng(sigma_hi * 10 + srate)
        n = 300
        fm, s, sa = _build(rng, sigma_hi, n, 16, srate=srate)
        B, L = 12, 5
        pats = np.full((B, L), PAD, np.int32)
        lens = rng.integers(1, L + 1, B)
        for i, m in enumerate(lens):
            pats[i, :m] = rng.integers(1, max(2, sigma_hi), m)
        k = fm.n  # k >= every count: full parity with the sorted oracle
        pos, cnt = locate(fm, jnp.asarray(pats), k)
        pos, cnt = np.asarray(pos), np.asarray(cnt)
        for i in range(B):
            want = np.asarray(locate_naive(fm, sa, jnp.asarray(pats[i])))
            nocc = int((want < fm.n).sum())
            assert cnt[i] == min(nocc, k)
            assert np.array_equal(pos[i, :nocc], want[:nocc]), i
            assert (pos[i, nocc:] == fm.n).all()

    def test_first_k_are_true_occurrences(self):
        """k < count: every returned position is a real occurrence."""
        rng = np.random.default_rng(3)
        fm, s, _sa = _build(rng, 3, 500, 16)
        pat = np.full((1, 4), PAD, np.int32)
        pat[0, :2] = [1, 2]
        k = 4
        pos, cnt = locate(fm, jnp.asarray(pat), k)
        pos, cnt = np.asarray(pos)[0], int(np.asarray(cnt)[0])
        assert count_naive(s, [1, 2]) >= cnt == k
        for p in pos:
            assert np.array_equal(s[p : p + 2], [1, 2])

    def test_requires_sa_samples(self):
        rng = np.random.default_rng(4)
        toks = rng.integers(1, 4, 64).astype(np.int32)
        s = al.append_sentinel(toks)
        sigma = al.sigma_of(s)
        b, row = bwt(jnp.asarray(s), sigma)
        fm = build_fm_index(b, row, sigma, 16)  # no sa=
        with pytest.raises(ValueError, match="locate"):
            locate(fm, jnp.zeros((1, 2), jnp.int32), 4)


class TestEdgeCases:
    def _fm(self, pack=None):
        rng = np.random.default_rng(9)
        return _build(rng, 4, 200, 16, pack=pack)

    @pytest.mark.parametrize("pack", [None, False])
    def test_all_pad_pattern_counts_everything(self, pack):
        fm, s, _ = self._fm(pack)
        pats = np.full((1, 5), PAD, np.int32)
        # an all-PAD pattern never narrows the interval: count == n
        assert int(count(fm, jnp.asarray(pats))[0]) == fm.n

    @pytest.mark.parametrize("pack", [None, False])
    def test_out_of_alphabet_empties_interval(self, pack):
        fm, s, _ = self._fm(pack)
        pats = np.full((3, 4), PAD, np.int32)
        pats[0, :2] = [1, 99]     # unknown symbol mid-pattern
        pats[1, :1] = [fm.sigma]  # first symbol outside [1, sigma)
        pats[2, :2] = [0, 1]      # sentinel is not queryable
        got = np.asarray(count(fm, jnp.asarray(pats)))
        assert list(got) == [0, 0, 0]

    @pytest.mark.parametrize("pack", [None, False])
    def test_pad_then_symbol_is_skipped(self, pack):
        """PADs on the right are no-ops, not separators."""
        fm, s, _ = self._fm(pack)
        pats = np.full((1, 6), PAD, np.int32)
        pats[0, :2] = [2, 3]
        want = count_naive(s, [2, 3])
        assert int(count(fm, jnp.asarray(pats))[0]) == want

    def test_locate_out_of_alphabet_returns_empty(self):
        fm, s, _ = self._fm()
        pats = np.full((1, 3), PAD, np.int32)
        pats[0, :2] = [1, 99]
        pos, cnt = locate(fm, jnp.asarray(pats), 8)
        assert int(np.asarray(cnt)[0]) == 0
        assert (np.asarray(pos)[0] == fm.n).all()
