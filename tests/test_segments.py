"""Segmented incremental append: exact parity with a monolithic index
(modulo documented boundary semantics), compaction, global coordinates,
catalog save/load, and serving through FMQueryServer."""

import numpy as np
import pytest

from repro.core.fm_index import PAD
from repro.core.pipeline import build_index
from repro.core.segments import SegmentedIndex
from repro.serving.engine import FMQueryServer

SIGMA = 7  # tokens 1..6
CHUNKS = (300, 150, 75, 512)


def _corpus(rng, sizes=CHUNKS, sigma=SIGMA):
    chunks = [rng.integers(1, sigma, n).astype(np.int32) for n in sizes]
    full = np.concatenate(chunks)
    offsets = np.cumsum([0] + [len(c) for c in chunks])[:-1]
    return chunks, full, offsets


def _patterns(rng, full, B=24, L=5):
    pats = np.full((B, L), PAD, np.int32)
    lens = rng.integers(1, L + 1, B)
    for b in range(B):
        st = rng.integers(0, len(full) - lens[b])
        pats[b, : lens[b]] = full[st : st + lens[b]]
    return pats, lens


def _occurrences(full, pat):
    """(within-segment positions, #cross-boundary) numpy oracle."""
    m = len(pat)
    w = np.lib.stride_tricks.sliding_window_view(full, m)
    return np.nonzero((w == pat).all(axis=1))[0]


def _split_hits(hits, offsets, m):
    cross = [p for p in hits if any(p < o < p + m for o in offsets[1:])]
    within = [p for p in hits if p not in cross]
    return within, cross


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(5)
    chunks, full, offsets = _corpus(rng)
    seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
    for c in chunks:
        seg.append(c)
    mono = build_index(full, sample_rate=16, sa_sample_rate=8)
    return rng, chunks, full, offsets, seg, mono


class TestAppendParity:
    def test_count_equals_monolithic_minus_boundary(self, built):
        """The exact boundary-semantics statement: segmented count ==
        monolithic count - occurrences spanning a segment boundary."""
        rng, _, full, offsets, seg, mono = built
        pats, lens = _patterns(rng, full)
        mono_cnt = np.asarray(mono.count(pats), np.int64)
        seg_cnt = seg.count(pats)
        for b in range(pats.shape[0]):
            hits = _occurrences(full, pats[b, : lens[b]])
            _, cross = _split_hits(hits, offsets, lens[b])
            assert seg_cnt[b] == mono_cnt[b] - len(cross), b

    def test_locate_global_positions(self, built):
        """Global positions == the monolithic position set restricted to
        within-segment occurrences."""
        rng, _, full, offsets, seg, _ = built
        pats, lens = _patterns(rng, full)
        k = 2 * len(full)  # no clipping: full position sets must match
        pos, cnt = seg.locate(pats, k)
        for b in range(pats.shape[0]):
            hits = _occurrences(full, pats[b, : lens[b]])
            within, _ = _split_hits(hits, offsets, lens[b])
            assert sorted(pos[b, : cnt[b]]) == sorted(within), b

    def test_offsets_and_catalog(self, built):
        _, chunks, _, offsets, seg, _ = built
        cat = seg.catalog()
        assert [c["offset"] for c in cat] == list(offsets)
        assert [c["n_tokens"] for c in cat] == [len(c) for c in chunks]
        assert seg.total_tokens == sum(len(c) for c in chunks)

    def test_declared_alphabet_enforced(self):
        seg = SegmentedIndex(4)
        with pytest.raises(ValueError, match="alphabet"):
            seg.append(np.array([1, 2, 7], np.int32))
        with pytest.raises(ValueError, match="empty"):
            seg.append(np.array([], np.int32))

    def test_token_absent_from_one_segment(self):
        """A query token present globally but absent from some segment must
        count 0 there (and not match that segment's padding)."""
        seg = SegmentedIndex(10, sample_rate=16, sa_sample_rate=8)
        seg.append(np.full(50, 2, np.int32))       # alphabet {2}
        seg.append(np.array([5] * 60, np.int32))   # alphabet {5}
        pats = np.full((2, 2), PAD, np.int32)
        pats[0, 0] = 5
        pats[1, :] = (2, 5)  # spans only a boundary -> 0 by semantics
        got = seg.count(pats)
        assert got[0] == 60 and got[1] == 0, got


class TestCompact:
    def test_compact_is_answer_invariant(self):
        """Compaction never changes an answer: documents keep their own
        sentinels inside the merged text, so counts AND (unclipped) locate
        sets are identical before and after — under both strategies."""
        rng = np.random.default_rng(9)
        chunks, full, offsets = _corpus(rng)
        for strategy in ("merge", "rebuild"):
            seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
            for c in chunks:
                seg.append(c)
            pats, lens = _patterns(rng, full)
            k = 2 * len(full)
            before_c = seg.count(pats)
            before_p, before_k = seg.locate(pats, k)
            assert seg.compact(strategy=strategy) == 1
            assert len(seg.segments) == 1 and seg.segments[0].multi_doc
            assert np.array_equal(seg.count(pats), before_c), strategy
            pos, cnt = seg.locate(pats, k)
            assert np.array_equal(pos, before_p), strategy
            assert np.array_equal(cnt, before_k), strategy
            # and the answers are exactly the within-document hits
            for b in range(pats.shape[0]):
                hits = _occurrences(full, pats[b, : lens[b]])
                within, _ = _split_hits(hits, offsets, lens[b])
                assert sorted(pos[b, : cnt[b]]) == sorted(within), b

    def test_merge_equals_rebuild_bit_identical(self):
        """The BWT-merge strategy must produce the very same FMIndex the
        raw-token rebuild produces — every array, every aux field."""
        rng = np.random.default_rng(19)
        chunks, _, _ = _corpus(rng)
        segs = {}
        for strategy in ("merge", "rebuild"):
            seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
            for c in chunks:
                seg.append(c)
            assert seg.compact(strategy=strategy) == 1
            segs[strategy] = seg.segments[0]
        a, b = segs["merge"], segs["rebuild"]
        assert a.docs == b.docs and a.offset == b.offset
        from repro.core.fm_index import fm_mismatch

        assert not (diff := fm_mismatch(a.index.fm, b.index.fm)), diff

    def test_compact_threshold_preserves_large_segments(self):
        rng = np.random.default_rng(10)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        sizes = (40, 30, 600, 25, 20)
        for n in sizes:
            seg.append(rng.integers(1, SIGMA, n).astype(np.int32))
        pats, _ = _patterns(rng, np.concatenate([s.tokens for s in seg.segments]))
        before = seg.count(pats)
        # merge only segments under 100 tokens: [40+30], [600], [25+20]
        assert seg.compact(min_tokens=100) == 2
        assert [s.n_tokens for s in seg.segments] == [70, 600, 45]
        assert [s.offset for s in seg.segments] == [0, 70, 670]
        # document semantics: compaction is answer-invariant, exactly
        assert np.array_equal(seg.count(pats), before)

    def test_compact_noop_on_single_segment(self):
        rng = np.random.default_rng(11)
        seg = SegmentedIndex(SIGMA)
        seg.append(rng.integers(1, SIGMA, 100).astype(np.int32))
        assert seg.compact() == 0 and len(seg.segments) == 1

    def test_maybe_compact_policy(self):
        """Cost-based background trigger: fires when the cheapest merge
        estimate undercuts the rebuild estimate; a run still needs >= 2
        adjacent smalls."""
        rng = np.random.default_rng(23)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             segment_min_tokens=100,
                             # make merging estimate-cheap for tiny runs so
                             # the cost trigger (not the backstop) decides
                             compact_cost_merge_us=0.0,
                             compact_cost_walk_ns=1.0,
                             compact_cost_token_ns=1.0)
        seg.append(rng.integers(1, SIGMA, 400).astype(np.int32))
        seg.append(rng.integers(1, SIGMA, 30).astype(np.int32))
        assert seg.maybe_compact() == 0      # a run needs >= 2 smalls
        seg.append(rng.integers(1, SIGMA, 40).astype(np.int32))
        assert seg.maybe_compact() == 1      # merge estimate beats rebuild
        assert [len(s.docs) for s in seg.segments] == [1, 2]
        assert seg.maybe_compact() == 0      # nothing small is adjacent

    def test_maybe_compact_cost_deferral_and_backstop(self):
        """When no merge flavor pays for itself vs the rebuild, runs defer
        — until the compact_max_small backstop bounds per-query fan-out.
        compact_cost_merge_us=0 disables the immediate-fire clause (a run
        whose rebuild costs less than one merge dispatch compacts right
        away), isolating the deferral path: equal tiny segments make the
        sequential walk estimate dominate the vectorized sort estimate."""
        rng = np.random.default_rng(29)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             segment_min_tokens=100, compact_max_small=4,
                             compact_cost_merge_us=0.0)
        for _ in range(3):
            seg.append(rng.integers(1, SIGMA, 30).astype(np.int32))
            assert seg.maybe_compact() == 0  # cost model defers
        seg.append(rng.integers(1, SIGMA, 30).astype(np.int32))
        assert seg.maybe_compact() == 1      # 4 smalls: backstop fires
        assert len(seg.segments) == 1 and len(seg.segments[0].docs) == 4


class TestLifecycle:
    def test_save_load_roundtrip(self, built, tmp_path):
        rng, chunks, full, _, seg, _ = built
        pats, _ = _patterns(rng, full)
        seg.save(str(tmp_path))
        loaded = SegmentedIndex.load(str(tmp_path))
        assert loaded.sigma == seg.sigma
        assert loaded.catalog() == seg.catalog()
        assert np.array_equal(seg.count(pats), loaded.count(pats))
        p0, c0 = seg.locate(pats, 64)
        p1, c1 = loaded.locate(pats, 64)
        assert np.array_equal(p0, p1) and np.array_equal(c0, c1)
        # the catalog keeps growing after restore
        loaded.append(rng.integers(1, SIGMA, 64).astype(np.int32))
        assert loaded.total_tokens == seg.total_tokens + 64

    def test_catalog_persists_build_knobs(self, tmp_path):
        """Knobs round-trip through catalog.json so post-restore compactions
        build segments exactly like the saved ones; kwargs still override."""
        rng = np.random.default_rng(12)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             pack=False, compress_sa=False,
                             segment_min_tokens=128)
        seg.append(rng.integers(1, SIGMA, 60).astype(np.int32))
        seg.append(rng.integers(1, SIGMA, 70).astype(np.int32))
        seg.save(str(tmp_path))
        loaded = SegmentedIndex.load(str(tmp_path))
        assert (loaded.pack, loaded.compress_sa) == (False, False)
        assert loaded.segment_min_tokens == 128
        assert loaded.sa_config == seg.sa_config
        # both segments are under the persisted threshold -> default compact
        # merges them, rebuilt with the persisted knobs
        assert loaded.compact() == 1
        assert loaded.segments[0].index.fm.bits == 0       # pack=False kept
        assert loaded.segments[0].index.fm.sa_val_bits == 0
        # explicit override wins over the catalog
        loaded2 = SegmentedIndex.load(str(tmp_path), sample_rate=32)
        assert loaded2.sample_rate == 32

    def test_from_config(self):
        from repro.configs.bwt_index import reduced

        cfg = reduced()
        seg = SegmentedIndex.from_config(SIGMA, cfg)
        assert seg.sample_rate == cfg.sample_rate
        assert seg.sa_sample_rate == cfg.sa_sample_rate
        assert seg.segment_min_tokens == cfg.segment_min_tokens
        assert seg.sa_config.engine == cfg.engine
        rng = np.random.default_rng(13)
        seg.append(rng.integers(1, SIGMA, 200).astype(np.int32))
        assert seg.count(np.array([[1]], np.int32))[0] > 0

    def test_save_is_incremental_and_gcs_orphans(self, tmp_path):
        """Re-saving skips persisted immutable segments; compact() orphans
        are removed so the directory tracks the live catalog."""
        import os

        rng = np.random.default_rng(14)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        seg.append(rng.integers(1, SIGMA, 60).astype(np.int32))
        seg.append(rng.integers(1, SIGMA, 70).astype(np.int32))
        seg.save(str(tmp_path))
        first = {d: os.path.getmtime(tmp_path / d / "tokens.npz")
                 for d in ("seg_000000", "seg_000001")}
        seg.save(str(tmp_path))  # no-op for existing segments
        for d, t in first.items():
            assert os.path.getmtime(tmp_path / d / "tokens.npz") == t, d
        assert seg.compact() == 1
        seg.save(str(tmp_path))
        dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("seg_"))
        assert dirs == ["seg_000002"]  # old segment dirs GC'd
        loaded = SegmentedIndex.load(str(tmp_path))
        assert loaded.catalog() == seg.catalog()

    def test_load_rejects_foreign_dir(self, tmp_path):
        (tmp_path / "catalog.json").write_text('{"format": "other"}')
        with pytest.raises(ValueError, match="catalog"):
            SegmentedIndex.load(str(tmp_path))

    def test_served_through_query_server(self, built):
        """FMQueryServer speaks SequenceIndex's interface; a SegmentedIndex
        drops in unchanged."""
        rng, _, full, offsets, seg, _ = built
        server = FMQueryServer(seg, length_buckets=(4, 8), max_batch=16)
        queries = [full[o : o + 3] for o in (0, 10, 400, 700)]
        got = server.count(queries)
        for q, g in zip(queries, got):
            hits = _occurrences(full, q)
            within, _ = _split_hits(hits, offsets, len(q))
            assert g == len(within)
        pos = server.locate([full[:4]], k=8)[0]
        assert 0 in pos


class TestMergeEdgeCases:
    """BWT-merge corner coverage: empty/one-symbol operands, SA-sample
    bit-width growth across a merge, and in-place stacked append after a
    merge (no recompilation)."""

    def _build_prepared(self, tokens, sigma_declared, r=8, srate=4):
        from repro.core.pipeline import build_index_prepared, prepare_tokens

        s, sig = prepare_tokens(np.asarray(tokens, np.int32), r,
                                sigma_declared)
        return build_index_prepared(s, sig, sample_rate=r,
                                    sa_sample_rate=srate), s, sig

    def _assert_merge_equals_rebuild(self, docs, sigma_declared, r=8,
                                     srate=4):
        from repro.core.bwt_merge import merge_fm_indexes
        from repro.core.pipeline import build_index_prepared, prepare_tokens

        preps = [prepare_tokens(np.asarray(d, np.int32), r,
                                sigma_declared)[0] for d in docs]
        sig = sigma_declared + 1
        acc = self._build_prepared(docs[-1], sigma_declared, r, srate)[0].fm
        for d in reversed(docs[:-1]):
            left = self._build_prepared(d, sigma_declared, r, srate)[0].fm
            acc = merge_fm_indexes(left, acc)
        want = build_index_prepared(
            np.concatenate(preps), sig, sample_rate=r, sa_sample_rate=srate,
        ).fm
        from repro.core.fm_index import fm_mismatch

        assert not (diff := fm_mismatch(acc, want)), diff
        return acc

    def test_empty_right_document(self):
        """An empty document (sentinel + pads only) merges exactly — as the
        right operand AND as the left."""
        rng = np.random.default_rng(31)
        body = rng.integers(1, 5, 20).astype(np.int32)
        self._assert_merge_equals_rebuild([body, []], 5)
        self._assert_merge_equals_rebuild([[], body], 5)
        self._assert_merge_equals_rebuild([[], []], 5)

    def test_single_symbol_segments(self):
        """Length-1 (and unary) segments: maximal padding, periodic merged
        text — the adversarial case for the interleave walk."""
        seg = SegmentedIndex(3, sample_rate=8, sa_sample_rate=4)
        for _ in range(3):
            seg.append(np.array([1], np.int32))
        pats = np.array([[1, PAD], [1, 1], [2, PAD]], np.int32)
        assert list(seg.count(pats)) == [3, 0, 0]
        before_p, before_c = seg.locate(pats, 8)
        assert seg.compact(strategy="merge") == 1
        assert list(seg.count(pats)) == [3, 0, 0]
        pos, cnt = seg.locate(pats, 8)
        assert np.array_equal(pos, before_p) and np.array_equal(cnt, before_c)
        self._assert_merge_equals_rebuild([[1], [1], [1]], 3)

    def test_sa_val_bits_grows_across_merge(self):
        """Merging can push the packed SA-value quotient past a power of
        two: the merged stream re-packs at the wider width, identical to
        what a rebuild computes."""
        rng = np.random.default_rng(33)
        seg = SegmentedIndex(5, sample_rate=8, sa_sample_rate=4)
        for _ in range(2):
            seg.append(rng.integers(1, 5, 27).astype(np.int32))
        per_seg_bits = {s.index.fm.sa_val_bits for s in seg.segments}
        assert per_seg_bits == {3}  # 32 positions / stride 4 -> q_max 7
        assert seg.compact(strategy="merge") == 1
        merged = seg.segments[0].index.fm
        assert merged.sa_val_bits == 4  # 64 positions -> q_max 15
        rng2 = np.random.default_rng(33)
        seg2 = SegmentedIndex(5, sample_rate=8, sa_sample_rate=4)
        for _ in range(2):
            seg2.append(rng2.integers(1, 5, 27).astype(np.int32))
        assert seg2.compact(strategy="rebuild") == 1
        assert seg2.segments[0].index.fm.sa_val_bits == 4
        assert np.array_equal(np.asarray(merged.sa_vals),
                              np.asarray(seg2.segments[0].index.fm.sa_vals))

    def test_merge_then_stacked_append_no_recompile(self):
        """After a merge compaction patched into the stacked catalog, an
        append into spare pow2 capacity must reuse the already-compiled
        stacked query program: n_seg is a pytree LEAF, and both the
        replace and the append preserve every static shape."""
        from repro.core.fm_index import StackedFMIndex, count_stacked

        rng = np.random.default_rng(37)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             parallel=True)
        seg.append(rng.integers(1, SIGMA, 700).astype(np.int32))
        for n in (40, 50, 30):
            seg.append(rng.integers(1, SIGMA, n).astype(np.int32))
        pats, _ = _patterns(rng, seg.segments[0].tokens, B=8, L=4)
        want = seg.count(pats)
        assert isinstance(seg._stacked_cache, StackedFMIndex)
        compiles_before = count_stacked._cache_size()
        st_before = seg._stacked_cache

        assert seg.compact(min_tokens=100, strategy="merge") == 1
        assert isinstance(seg._stacked_cache, StackedFMIndex), \
            "merge within the block bucket must patch the cache in place"
        assert np.array_equal(seg.count(pats), want)

        seg.append(rng.integers(1, SIGMA, 35).astype(np.int32))
        assert seg._stacked_cache is not None
        assert int(seg._stacked_cache.n_seg) == 3
        assert seg._stacked_cache.seg_pad == st_before.seg_pad
        assert seg._stacked_cache.blocks_pad == st_before.blocks_pad
        got = seg.count(pats)
        assert count_stacked._cache_size() == compiles_before, \
            "stacked append/replace recompiled the query program"
        # sequential path agrees with the patched stacked catalog
        seg.parallel = False
        seq = seg.count(pats)
        seg.parallel = True
        assert np.array_equal(got, seq)


class TestKWayAndPlanner:
    """K-way interleave merge + cost-based planner: merged-of-merged
    operands on BOTH sides (the PR 5 'multi-doc only as the right operand'
    restriction is gone), bit-identity against the rebuild oracle across
    alphabets, direct merge_kway conformance, and rebuild-fallback
    telemetry for context-order-unsafe runs."""

    def _grow(self, seed, sigma, sizes, strategy, r=8, srate=4):
        rng = np.random.default_rng(seed)
        seg = SegmentedIndex(sigma, sample_rate=r, sa_sample_rate=srate,
                             compact_strategy=strategy)
        for n in sizes:
            seg.append(rng.integers(1, sigma, n).astype(np.int32))
        return seg

    @pytest.mark.parametrize("sigma", [2, 4, 16, 17])
    def test_merged_of_merged_both_sides(self, sigma):
        """Two already-merged multi-doc segments compact into one,
        bit-identical to the rebuild, under every strategy."""
        from repro.core.fm_index import fm_mismatch

        sizes = (57, 33, 41, 29)
        final = {}
        for strategy in ("kway", "pairwise", "merge", "rebuild"):
            seg = self._grow(41 + sigma, sigma, sizes, strategy)
            # pre-merge adjacent pairs -> two multi-doc segments
            a = seg._merge_run(seg.segments[:2], "rebuild")
            b = seg._merge_run(seg.segments[2:], "rebuild")
            seg.segments = [a, b]
            seg._stacked_cache = None
            assert all(s.multi_doc for s in seg.segments)
            assert seg.compact(strategy=strategy) == 1
            final[strategy] = seg
        want = final["rebuild"].segments[0].index.fm
        for strategy in ("kway", "pairwise", "merge"):
            got = final[strategy].segments[0].index.fm
            assert not (d := fm_mismatch(got, want)), (strategy, d)
            # answer-invariance on top of bit-identity
            assert final[strategy].segments[0].docs == \
                final["rebuild"].segments[0].docs

    def test_kway_runs_without_fallback_on_typical_text(self):
        """Random multi-doc corpora are context-order safe in practice
        (document pads sort above every real token): the forced k-way
        strategy must actually run the k-way walk, not fall back."""
        seg = self._grow(57, 16, (57, 33, 41, 29), "kway")
        assert seg.compact(strategy="kway") == 1
        assert seg.compact_fallbacks == 0
        assert seg.compact_strategy_counts == {"kway": 1}
        plan = seg.compact_last_plan
        assert plan["strategy"] == "kway" and plan["reason"] is None
        assert plan["actual_walk_steps"] == plan["est_walk_steps"] > 0

    @pytest.mark.parametrize("sigma", [2, 4, 16, 17])
    def test_direct_merge_kway_matches_build(self, sigma):
        """merge_kway on k=4 prepared docs == build_index_prepared on
        their concatenation — every array, every aux field."""
        from repro.core.bwt_merge import context_order_safe, merge_kway
        from repro.core.fm_index import fm_mismatch
        from repro.core.pipeline import build_index_prepared, prepare_tokens

        r, srate = 8, 4
        rng = np.random.default_rng(67 + sigma)
        docs = [rng.integers(1, sigma, n).astype(np.int32)
                for n in (45, 30, 22, 11)]
        preps, sigs, fms = [], [], []
        for d in docs:
            s, sig = prepare_tokens(d, r, sigma)
            preps.append(s)
            sigs.append(sig)
            fms.append(build_index_prepared(
                s, sig, sample_rate=r, sa_sample_rate=srate).fm)
        for i in range(len(preps) - 1):  # precondition of the k-way walk
            assert context_order_safe(preps[i], np.concatenate(preps[i+1:]))
        got = merge_kway(fms)
        want = build_index_prepared(
            np.concatenate(preps), max(sigs), sample_rate=r,
            sa_sample_rate=srate).fm
        assert not (d := fm_mismatch(got, want)), d

    def test_unsafe_run_falls_back_with_telemetry(self):
        """A run no candidate order can rescue — two *identical* merged
        multi-doc segments whose texts end in a bare sentinel (the
        self-similar tied tail is context-order unsafe in either
        direction, and there is no single-doc segment to lead with) —
        must NOT merge silently wrong: the planner detects it, warns,
        counts the fallback, and the rebuild stays bit-identical to the
        oracle."""
        import warnings as _w

        from repro.core.fm_index import fm_mismatch

        r, srate, sigma = 8, 4, 4
        d1 = np.full(7, 3, np.int32)  # 7 + sentinel = block: no pads
        d2 = np.full(7, 1, np.int32)  # merged [d1,d2] text ends with 0

        def grow(strategy):
            seg = SegmentedIndex(sigma, sample_rate=r, sa_sample_rate=srate,
                                 compact_strategy=strategy)
            for d in (d1, d2, d1, d2):
                seg.append(d)
            for lo in (2, 0):  # pre-merge (d1,d2) pairs -> two multis
                m = seg._merge_run(seg.segments[lo : lo + 2], "rebuild")
                seg.segments = (seg.segments[:lo] + [m]
                                + seg.segments[lo + 2 :])
            seg._stacked_cache = None
            seg.compact_strategy_counts = {}  # drop the setup merges' counts
            return seg

        oracle = grow("rebuild")
        assert oracle.compact() == 1
        for strategy in ("kway", "pairwise", "merge"):
            seg = grow(strategy)
            with pytest.warns(RuntimeWarning, match="fell back"):
                assert seg.compact(strategy=strategy) == 1
            assert seg.compact_fallbacks == 1
            assert "context-order" in seg.compact_last_fallback_reason
            assert seg.compact_strategy_counts == {"rebuild": 1}
            assert not fm_mismatch(seg.segments[0].index.fm,
                                   oracle.segments[0].index.fm)
        _w.simplefilter("default")

    def test_fallback_telemetry_survives_save_load(self, tmp_path):
        """compact_fallbacks / last reason persist through the catalog."""
        seg = SegmentedIndex(4, sample_rate=8, sa_sample_rate=4,
                             compact_strategy="kway")
        for d in (np.full(7, 3, np.int32), np.full(7, 1, np.int32)) * 2:
            seg.append(d)
        for lo in (2, 0):  # two identical multis: unrescuably unsafe
            m = seg._merge_run(seg.segments[lo : lo + 2], "rebuild")
            seg.segments = seg.segments[:lo] + [m] + seg.segments[lo + 2 :]
        seg._stacked_cache = None
        with pytest.warns(RuntimeWarning):
            seg.compact()
        seg.save(str(tmp_path))
        loaded = SegmentedIndex.load(str(tmp_path))
        assert loaded.compact_fallbacks == seg.compact_fallbacks == 1
        assert loaded.compact_last_fallback_reason == \
            seg.compact_last_fallback_reason
