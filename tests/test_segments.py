"""Segmented incremental append: exact parity with a monolithic index
(modulo documented boundary semantics), compaction, global coordinates,
catalog save/load, and serving through FMQueryServer."""

import numpy as np
import pytest

from repro.core.fm_index import PAD
from repro.core.pipeline import build_index
from repro.core.segments import SegmentedIndex
from repro.serving.engine import FMQueryServer

SIGMA = 7  # tokens 1..6
CHUNKS = (300, 150, 75, 512)


def _corpus(rng, sizes=CHUNKS, sigma=SIGMA):
    chunks = [rng.integers(1, sigma, n).astype(np.int32) for n in sizes]
    full = np.concatenate(chunks)
    offsets = np.cumsum([0] + [len(c) for c in chunks])[:-1]
    return chunks, full, offsets


def _patterns(rng, full, B=24, L=5):
    pats = np.full((B, L), PAD, np.int32)
    lens = rng.integers(1, L + 1, B)
    for b in range(B):
        st = rng.integers(0, len(full) - lens[b])
        pats[b, : lens[b]] = full[st : st + lens[b]]
    return pats, lens


def _occurrences(full, pat):
    """(within-segment positions, #cross-boundary) numpy oracle."""
    m = len(pat)
    w = np.lib.stride_tricks.sliding_window_view(full, m)
    return np.nonzero((w == pat).all(axis=1))[0]


def _split_hits(hits, offsets, m):
    cross = [p for p in hits if any(p < o < p + m for o in offsets[1:])]
    within = [p for p in hits if p not in cross]
    return within, cross


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(5)
    chunks, full, offsets = _corpus(rng)
    seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
    for c in chunks:
        seg.append(c)
    mono = build_index(full, sample_rate=16, sa_sample_rate=8)
    return rng, chunks, full, offsets, seg, mono


class TestAppendParity:
    def test_count_equals_monolithic_minus_boundary(self, built):
        """The exact boundary-semantics statement: segmented count ==
        monolithic count - occurrences spanning a segment boundary."""
        rng, _, full, offsets, seg, mono = built
        pats, lens = _patterns(rng, full)
        mono_cnt = np.asarray(mono.count(pats), np.int64)
        seg_cnt = seg.count(pats)
        for b in range(pats.shape[0]):
            hits = _occurrences(full, pats[b, : lens[b]])
            _, cross = _split_hits(hits, offsets, lens[b])
            assert seg_cnt[b] == mono_cnt[b] - len(cross), b

    def test_locate_global_positions(self, built):
        """Global positions == the monolithic position set restricted to
        within-segment occurrences."""
        rng, _, full, offsets, seg, _ = built
        pats, lens = _patterns(rng, full)
        k = 2 * len(full)  # no clipping: full position sets must match
        pos, cnt = seg.locate(pats, k)
        for b in range(pats.shape[0]):
            hits = _occurrences(full, pats[b, : lens[b]])
            within, _ = _split_hits(hits, offsets, lens[b])
            assert sorted(pos[b, : cnt[b]]) == sorted(within), b

    def test_offsets_and_catalog(self, built):
        _, chunks, _, offsets, seg, _ = built
        cat = seg.catalog()
        assert [c["offset"] for c in cat] == list(offsets)
        assert [c["n_tokens"] for c in cat] == [len(c) for c in chunks]
        assert seg.total_tokens == sum(len(c) for c in chunks)

    def test_declared_alphabet_enforced(self):
        seg = SegmentedIndex(4)
        with pytest.raises(ValueError, match="alphabet"):
            seg.append(np.array([1, 2, 7], np.int32))
        with pytest.raises(ValueError, match="empty"):
            seg.append(np.array([], np.int32))

    def test_token_absent_from_one_segment(self):
        """A query token present globally but absent from some segment must
        count 0 there (and not match that segment's padding)."""
        seg = SegmentedIndex(10, sample_rate=16, sa_sample_rate=8)
        seg.append(np.full(50, 2, np.int32))       # alphabet {2}
        seg.append(np.array([5] * 60, np.int32))   # alphabet {5}
        pats = np.full((2, 2), PAD, np.int32)
        pats[0, 0] = 5
        pats[1, :] = (2, 5)  # spans only a boundary -> 0 by semantics
        got = seg.count(pats)
        assert got[0] == 60 and got[1] == 0, got


class TestCompact:
    def test_compact_all_equals_monolithic(self):
        rng = np.random.default_rng(9)
        chunks, full, _ = _corpus(rng)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        for c in chunks:
            seg.append(c)
        mono = build_index(full, sample_rate=16, sa_sample_rate=8)
        assert seg.compact() == 1 and len(seg.segments) == 1
        pats, lens = _patterns(rng, full)
        assert np.array_equal(seg.count(pats),
                              np.asarray(mono.count(pats), np.int64))
        k = 2 * len(full)
        pos, cnt = seg.locate(pats, k)
        for b in range(pats.shape[0]):
            hits = _occurrences(full, pats[b, : lens[b]])
            assert sorted(pos[b, : cnt[b]]) == sorted(hits), b

    def test_compact_threshold_preserves_large_segments(self):
        rng = np.random.default_rng(10)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        sizes = (40, 30, 600, 25, 20)
        for n in sizes:
            seg.append(rng.integers(1, SIGMA, n).astype(np.int32))
        pats, _ = _patterns(rng, np.concatenate([s.tokens for s in seg.segments]))
        before = seg.count(pats)
        # merge only segments under 100 tokens: [40+30], [600], [25+20]
        assert seg.compact(min_tokens=100) == 2
        assert [s.n_tokens for s in seg.segments] == [70, 600, 45]
        assert [s.offset for s in seg.segments] == [0, 70, 670]
        after = seg.count(pats)
        # merged runs may only ADD previously-missed boundary matches
        assert np.all(after >= before)

    def test_compact_noop_on_single_segment(self):
        rng = np.random.default_rng(11)
        seg = SegmentedIndex(SIGMA)
        seg.append(rng.integers(1, SIGMA, 100).astype(np.int32))
        assert seg.compact() == 0 and len(seg.segments) == 1


class TestLifecycle:
    def test_save_load_roundtrip(self, built, tmp_path):
        rng, chunks, full, _, seg, _ = built
        pats, _ = _patterns(rng, full)
        seg.save(str(tmp_path))
        loaded = SegmentedIndex.load(str(tmp_path))
        assert loaded.sigma == seg.sigma
        assert loaded.catalog() == seg.catalog()
        assert np.array_equal(seg.count(pats), loaded.count(pats))
        p0, c0 = seg.locate(pats, 64)
        p1, c1 = loaded.locate(pats, 64)
        assert np.array_equal(p0, p1) and np.array_equal(c0, c1)
        # the catalog keeps growing after restore
        loaded.append(rng.integers(1, SIGMA, 64).astype(np.int32))
        assert loaded.total_tokens == seg.total_tokens + 64

    def test_catalog_persists_build_knobs(self, tmp_path):
        """Knobs round-trip through catalog.json so post-restore compactions
        build segments exactly like the saved ones; kwargs still override."""
        rng = np.random.default_rng(12)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8,
                             pack=False, compress_sa=False,
                             segment_min_tokens=128)
        seg.append(rng.integers(1, SIGMA, 60).astype(np.int32))
        seg.append(rng.integers(1, SIGMA, 70).astype(np.int32))
        seg.save(str(tmp_path))
        loaded = SegmentedIndex.load(str(tmp_path))
        assert (loaded.pack, loaded.compress_sa) == (False, False)
        assert loaded.segment_min_tokens == 128
        assert loaded.sa_config == seg.sa_config
        # both segments are under the persisted threshold -> default compact
        # merges them, rebuilt with the persisted knobs
        assert loaded.compact() == 1
        assert loaded.segments[0].index.fm.bits == 0       # pack=False kept
        assert loaded.segments[0].index.fm.sa_val_bits == 0
        # explicit override wins over the catalog
        loaded2 = SegmentedIndex.load(str(tmp_path), sample_rate=32)
        assert loaded2.sample_rate == 32

    def test_from_config(self):
        from repro.configs.bwt_index import reduced

        cfg = reduced()
        seg = SegmentedIndex.from_config(SIGMA, cfg)
        assert seg.sample_rate == cfg.sample_rate
        assert seg.sa_sample_rate == cfg.sa_sample_rate
        assert seg.segment_min_tokens == cfg.segment_min_tokens
        assert seg.sa_config.engine == cfg.engine
        rng = np.random.default_rng(13)
        seg.append(rng.integers(1, SIGMA, 200).astype(np.int32))
        assert seg.count(np.array([[1]], np.int32))[0] > 0

    def test_save_is_incremental_and_gcs_orphans(self, tmp_path):
        """Re-saving skips persisted immutable segments; compact() orphans
        are removed so the directory tracks the live catalog."""
        import os

        rng = np.random.default_rng(14)
        seg = SegmentedIndex(SIGMA, sample_rate=16, sa_sample_rate=8)
        seg.append(rng.integers(1, SIGMA, 60).astype(np.int32))
        seg.append(rng.integers(1, SIGMA, 70).astype(np.int32))
        seg.save(str(tmp_path))
        first = {d: os.path.getmtime(tmp_path / d / "tokens.npz")
                 for d in ("seg_000000", "seg_000001")}
        seg.save(str(tmp_path))  # no-op for existing segments
        for d, t in first.items():
            assert os.path.getmtime(tmp_path / d / "tokens.npz") == t, d
        assert seg.compact() == 1
        seg.save(str(tmp_path))
        dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("seg_"))
        assert dirs == ["seg_000002"]  # old segment dirs GC'd
        loaded = SegmentedIndex.load(str(tmp_path))
        assert loaded.catalog() == seg.catalog()

    def test_load_rejects_foreign_dir(self, tmp_path):
        (tmp_path / "catalog.json").write_text('{"format": "other"}')
        with pytest.raises(ValueError, match="catalog"):
            SegmentedIndex.load(str(tmp_path))

    def test_served_through_query_server(self, built):
        """FMQueryServer speaks SequenceIndex's interface; a SegmentedIndex
        drops in unchanged."""
        rng, _, full, offsets, seg, _ = built
        server = FMQueryServer(seg, length_buckets=(4, 8), max_batch=16)
        queries = [full[o : o + 3] for o in (0, 10, 400, 700)]
        got = server.count(queries)
        for q, g in zip(queries, got):
            hits = _occurrences(full, q)
            within, _ = _split_hits(hits, offsets, len(q))
            assert g == len(within)
        pos = server.locate([full[:4]], k=8)[0]
        assert 0 in pos
