"""Index checkpoint/restore: bit-identical roundtrips, packing boundaries,
compressed SA samples, manifest versioning, and the re-mesh scenario."""

import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import alphabet as al
from repro.core.bwt import bwt_from_sa
from repro.core.fm_index import (
    PAD,
    build_fm_index,
    build_sa_samples,
    count,
    locate,
    pack_sa_values,
    unpack_sa_value,
)
from repro.core.index_io import (
    CorruptCheckpointError,
    IndexIOError,
    MissingCheckpointError,
    UnsupportedVersionError,
    describe_index,
    latest_index_step,
    restore_index,
    save_index,
)
from repro.core.pipeline import build_index
from repro.core.suffix_array import suffix_array

DRIVER = os.path.join(os.path.dirname(__file__), "dist_driver.py")


def _random_patterns(rng, toks, B=8, L=6):
    pats = np.full((B, L), PAD, np.int32)
    lens = rng.integers(1, L + 1, B)
    for b in range(B):
        st = rng.integers(0, len(toks) - lens[b])
        pats[b, : lens[b]] = toks[st : st + lens[b]]
    return pats


def _assert_same_index(a, b, pats, k=64):
    """count/locate parity plus leaf-level bit identity."""
    assert np.array_equal(np.asarray(a.count(pats)), np.asarray(b.count(pats)))
    pa, ca = (np.asarray(x) for x in a.locate(pats, k))
    pb, cb = (np.asarray(x) for x in b.locate(pats, k))
    assert np.array_equal(pa, pb) and np.array_equal(ca, cb)
    la = jax.tree_util.tree_leaves(a.fm)
    lb = jax.tree_util.tree_leaves(b.fm)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y))


class TestRoundtrip:
    def test_bit_identical(self, tmp_path):
        rng = np.random.default_rng(0)
        toks = rng.integers(1, 5, 777).astype(np.int32)
        idx = build_index(toks, sample_rate=16, sa_sample_rate=8)
        save_index(str(tmp_path), idx)
        rest = restore_index(str(tmp_path))
        _assert_same_index(idx, rest, _random_patterns(rng, toks))
        assert rest.text_length == idx.text_length

    def test_no_sa_sample(self, tmp_path):
        """Empty SA sample (sa_sample_rate=0): roundtrips, locate raises."""
        rng = np.random.default_rng(1)
        toks = rng.integers(1, 5, 300).astype(np.int32)
        idx = build_index(toks, sample_rate=16, sa_sample_rate=0)
        save_index(str(tmp_path), idx)
        rest = restore_index(str(tmp_path))
        pats = _random_patterns(rng, toks)
        assert np.array_equal(np.asarray(idx.count(pats)),
                              np.asarray(rest.count(pats)))
        assert rest.fm.sa_vals is None and rest.fm.sa_sample_rate == 0
        with pytest.raises(ValueError, match="locate unavailable"):
            rest.locate(pats, 4)

    @pytest.mark.parametrize("sigma,want_bits", [
        (4, 2),    # 2-bit packing
        (16, 4),   # 4-bit packing, at the boundary
        (17, 0),   # one past the boundary: unpacked layout
    ])
    def test_packing_boundary(self, tmp_path, sigma, want_bits):
        """sigma = 16 (sentinel + 15 symbols) is the last packable alphabet;
        17 falls back to the unpacked layout — both roundtrip bit-identically."""
        rng = np.random.default_rng(2)
        r = 16
        toks = rng.integers(1, sigma, 16 * r - 1).astype(np.int32)
        toks[: sigma - 1] = np.arange(1, sigma)  # realise the full alphabet
        s = al.append_sentinel(toks)
        assert al.sigma_of(s) == sigma
        sd = jnp.asarray(s)
        sa = suffix_array(sd, sigma)
        bwt_arr, row = bwt_from_sa(sd, sa)
        fm = build_fm_index(bwt_arr, row, sigma, r, sa=sa, sa_sample_rate=4)
        assert fm.bits == want_bits
        save_index(str(tmp_path), fm)
        info = describe_index(str(tmp_path))
        assert info.bits == want_bits and info.kind == "fm"
        rest = restore_index(str(tmp_path))
        assert rest.fm.bits == want_bits
        pats = jnp.asarray(_random_patterns(rng, toks))
        assert np.array_equal(np.asarray(count(fm, pats)),
                              np.asarray(rest.count(pats)))
        pa, ca = (np.asarray(x) for x in locate(fm, pats, 32))
        pb, cb = (np.asarray(x) for x in rest.locate(pats, 32))
        assert np.array_equal(pa, pb) and np.array_equal(ca, cb)

    def test_uncompressed_sa_roundtrip(self, tmp_path):
        rng = np.random.default_rng(3)
        toks = rng.integers(1, 5, 500).astype(np.int32)
        idx = build_index(toks, sample_rate=16, sa_sample_rate=8,
                          compress_sa=False)
        assert idx.fm.sa_val_bits == 0
        save_index(str(tmp_path), idx)
        rest = restore_index(str(tmp_path))
        assert rest.fm.sa_val_bits == 0
        _assert_same_index(idx, rest, _random_patterns(rng, toks))

    def test_keep_k_steps(self, tmp_path):
        rng = np.random.default_rng(4)
        toks = rng.integers(1, 5, 200).astype(np.int32)
        idx = build_index(toks, sample_rate=16)
        for step in (1, 2, 3):
            save_index(str(tmp_path), idx, step=step, keep=2)
        assert latest_index_step(str(tmp_path)) == 3
        pats = _random_patterns(rng, toks)
        rest = restore_index(str(tmp_path), step=2)
        assert np.array_equal(np.asarray(idx.count(pats)),
                              np.asarray(rest.count(pats)))


class TestManifest:
    def test_version_guard(self, tmp_path):
        rng = np.random.default_rng(5)
        idx = build_index(rng.integers(1, 5, 200).astype(np.int32),
                          sample_rate=16)
        save_index(str(tmp_path), idx)
        meta_path = tmp_path / "step_00000000" / "meta.json"
        import json
        meta = json.loads(meta_path.read_text())
        meta["version"] = 999
        meta_path.write_text(json.dumps(meta))
        with pytest.raises(ValueError, match="newer"):
            restore_index(str(tmp_path))

    def test_not_an_index(self, tmp_path):
        from repro.training.checkpoint import Checkpointer

        Checkpointer(str(tmp_path)).save(0, {"x": jnp.zeros(4)})
        with pytest.raises(ValueError, match="not an index checkpoint"):
            restore_index(str(tmp_path))

    def test_describe_empty(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            describe_index(str(tmp_path))

    def test_read_paths_do_not_create_directories(self, tmp_path):
        """Restoring/describing a mistyped path must not leave an empty
        directory tree behind (Checkpointer creates dirs lazily, on save)."""
        missing = tmp_path / "no" / "such" / "index"
        with pytest.raises(FileNotFoundError):
            restore_index(str(missing))
        assert latest_index_step(str(missing)) is None
        assert not missing.exists()


class TestTypedErrors:
    """Every restore failure mode raises a typed, actionable IndexIOError
    subclass that ALSO derives from the stdlib exception older callers
    caught (FileNotFoundError / ValueError)."""

    @pytest.fixture()
    def saved(self, tmp_path):
        rng = np.random.default_rng(9)
        toks = rng.integers(1, 5, 300).astype(np.int32)
        idx = build_index(toks, sample_rate=16, sa_sample_rate=8)
        save_index(str(tmp_path), idx)
        return tmp_path

    def test_empty_dir_is_missing(self, tmp_path):
        with pytest.raises(MissingCheckpointError) as ei:
            restore_index(str(tmp_path))
        assert isinstance(ei.value, FileNotFoundError)
        assert "save_index" in str(ei.value)  # actionable: how to make one

    def test_missing_manifest(self, saved):
        (saved / "step_00000000" / "meta.json").unlink()
        with pytest.raises(MissingCheckpointError):
            restore_index(str(saved))
        with pytest.raises(MissingCheckpointError, match="torn"):
            describe_index(str(saved))

    def test_version_from_the_future_is_typed(self, saved):
        import json
        meta_path = saved / "step_00000000" / "meta.json"
        meta = json.loads(meta_path.read_text())
        meta["version"] = 999
        meta_path.write_text(json.dumps(meta))
        with pytest.raises(UnsupportedVersionError, match="newer") as ei:
            restore_index(str(saved))
        assert isinstance(ei.value, (IndexIOError, ValueError))
        with pytest.raises(UnsupportedVersionError):
            describe_index(str(saved))

    def test_truncated_arrays_file(self, saved):
        """A torn arrays.npz (half the bytes) is corruption, not a crash
        with a zipfile traceback."""
        path = saved / "step_00000000" / "arrays.npz"
        blob = path.read_bytes()
        path.write_bytes(blob[: len(blob) // 2])
        with pytest.raises(CorruptCheckpointError, match="unreadable") as ei:
            restore_index(str(saved))
        assert isinstance(ei.value, ValueError)

    def test_missing_declared_array(self, saved):
        """arrays.npz missing a leaf the manifest declares -> corrupt, with
        the missing names listed."""
        path = saved / "step_00000000" / "arrays.npz"
        with np.load(str(path)) as z:
            flat = {k: z[k] for k in z.files if k != "row"}
        np.savez(str(path), **flat)
        with pytest.raises(CorruptCheckpointError, match="row"):
            restore_index(str(saved))

    def test_truncated_bwt_array(self, saved):
        """A bwt shorter than the manifest's length -> corrupt (truncated),
        caught before any index math runs."""
        path = saved / "step_00000000" / "arrays.npz"
        with np.load(str(path)) as z:
            flat = {k: z[k] for k in z.files}
        flat["bwt"] = flat["bwt"][: len(flat["bwt"]) // 2]
        np.savez(str(path), **flat)
        with pytest.raises(CorruptCheckpointError, match="truncated"):
            restore_index(str(saved))

    def test_unreadable_manifest_json(self, saved):
        (saved / "step_00000000" / "meta.json").write_text("{not json")
        with pytest.raises(CorruptCheckpointError):
            restore_index(str(saved))
        with pytest.raises(CorruptCheckpointError, match="unreadable"):
            describe_index(str(saved))

    def test_family_catch_all(self, saved):
        """One except clause covers the whole family."""
        (saved / "step_00000000" / "meta.json").unlink()
        with pytest.raises(IndexIOError):
            restore_index(str(saved))


class TestCompressedSAValues:
    def test_pack_unpack_exhaustive_widths(self):
        rng = np.random.default_rng(6)
        for bits in (1, 3, 7, 11, 12, 17, 23, 31):
            n = 257
            q = rng.integers(0, 1 << bits, n, dtype=np.int64)
            packed = jnp.asarray(pack_sa_values(q, bits))
            got = unpack_sa_value(packed, jnp.arange(n, dtype=jnp.int32), bits)
            assert np.array_equal(np.asarray(got), q), bits

    def test_build_sa_samples_parity(self):
        rng = np.random.default_rng(7)
        sa = jnp.asarray(rng.permutation(4096).astype(np.int32))
        mr, rr, vr, br = build_sa_samples(sa, 4, compress=False)
        mc, rc, vc, bc = build_sa_samples(sa, 4, compress=True)
        assert br == 0 and bc == 10  # 1024 sampled values -> 10 bits each
        assert vc.shape[0] < vr.shape[0] // 2  # genuinely smaller
        got = unpack_sa_value(vc, jnp.arange(vr.shape[0], dtype=jnp.int32), bc)
        assert np.array_equal(np.asarray(got) * 4, np.asarray(vr))

    def test_locate_parity_small_stride(self):
        """The compressed decode is exercised on every locate step."""
        rng = np.random.default_rng(8)
        toks = rng.integers(1, 5, 2000).astype(np.int32)
        raw = build_index(toks, sample_rate=16, sa_sample_rate=4,
                          compress_sa=False)
        cmp_ = build_index(toks, sample_rate=16, sa_sample_rate=4,
                           compress_sa=True)
        assert cmp_.fm.sa_val_bits > 0
        pats = _random_patterns(rng, toks, B=16)
        pr, cr = (np.asarray(x) for x in raw.locate(pats, 128))
        pc, cc = (np.asarray(x) for x in cmp_.locate(pats, 128))
        assert np.array_equal(pr, pc) and np.array_equal(cr, cc)


def test_restore_across_device_counts():
    """8-shard checkpoint serves from 8, 4, and 1 device(s) bit-identically
    (subprocess with forced host devices, like tests/test_distributed.py)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, DRIVER, "index_io", "8"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, (
        f"index_io failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
        f"STDERR:{proc.stderr[-3000:]}"
    )
