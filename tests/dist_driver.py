"""Multi-device correctness driver, run in a SUBPROCESS with forced host
devices (so the main pytest process keeps the default single device).

Usage: python tests/dist_driver.py <scenario> [devices]
Exits 0 on success; prints failures and exits 1 otherwise.
"""

import os
import sys

DEVICES = int(sys.argv[2]) if len(sys.argv) > 2 else 8
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={DEVICES} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.compat import shard_map  # noqa: E402
from repro.core import alphabet as al  # noqa: E402
from repro.core.dist_sort import (  # noqa: E402
    ShardInfo,
    bitonic_sort_sharded,
    exclusive_scan_sharded,
    samplesort_sharded,
    scatter_to_index_bitonic,
    scatter_to_index_samplesort,
    shift_sharded,
)
from repro.core.dist_suffix_array import (  # noqa: E402
    BITONIC,
    SAMPLESORT,
    DistSAConfig,
    build_bwt_sharded,
    build_isa_sharded,
    isa_overflowed,
)
from repro.core.suffix_array import suffix_array_naive  # noqa: E402
from repro.core.bwt import bwt_naive  # noqa: E402

from jax.sharding import PartitionSpec as P  # noqa: E402

AXIS = "parts"


def make_mesh():
    return jax.make_mesh((DEVICES,), (AXIS,))


def shard_call(mesh, fn, *arrays, out_specs=P(AXIS)):
    return jax.jit(
        shard_map(
            fn, mesh=mesh, in_specs=tuple(P(AXIS) for _ in arrays),
            out_specs=out_specs,
        )
    )(*arrays)


def scenario_bitonic_sort():
    mesh = make_mesh()
    rng = np.random.default_rng(0)
    for trial in range(5):
        n = DEVICES * int(rng.integers(4, 40))
        info = ShardInfo(AXIS, DEVICES, n // DEVICES)
        k1 = rng.integers(0, 10, n).astype(np.int32)
        k2 = rng.integers(-1, 10, n).astype(np.int32)
        pay = np.arange(n, dtype=np.int32)

        def f(a, b, c):
            return bitonic_sort_sharded(info, (a, b, c), num_keys=2)

        r1, r2, rp = shard_call(
            mesh, f, jnp.asarray(k1), jnp.asarray(k2), jnp.asarray(pay),
            out_specs=(P(AXIS),) * 3,
        )
        order = np.lexsort((pay, k2, k1))
        assert np.array_equal(np.asarray(r1), k1[order]), "keys1 mismatch"
        assert np.array_equal(np.asarray(r2), k2[order]), "keys2 mismatch"
        # payload: equal keys may permute payloads; verify (k1,k2,pay) multiset
        got = sorted(zip(np.asarray(r1), np.asarray(r2), np.asarray(rp)))
        want = sorted(zip(k1, k2, pay))
        assert got == want, "payload multiset mismatch"
    print("bitonic sort ok")


def scenario_shift():
    mesh = make_mesh()
    rng = np.random.default_rng(1)
    n = DEVICES * 16
    info = ShardInfo(AXIS, DEVICES, n // DEVICES)
    x = rng.integers(0, 100, n).astype(np.int32)
    for h in [1, 2, 3, 15, 16, 17, 64, n - 1]:
        def f(a):
            return shift_sharded(info, a, h, -1)

        out = np.asarray(shard_call(mesh, f, jnp.asarray(x)))
        want = np.full(n, -1, np.int32)
        want[: n - h] = x[h:]
        assert np.array_equal(out, want), f"shift h={h}"
    print("shift ok")


def scenario_scan():
    mesh = make_mesh()
    rng = np.random.default_rng(2)
    info = ShardInfo(AXIS, DEVICES, 1)
    v = rng.integers(0, 50, DEVICES).astype(np.int32)

    def f(a):
        return exclusive_scan_sharded(info, a[0])[None]

    out = np.asarray(shard_call(mesh, f, jnp.asarray(v)))
    want = np.cumsum(v) - v
    assert np.array_equal(out, want), (out, want)
    print("scan ok")


def scenario_samplesort():
    mesh = make_mesh()
    rng = np.random.default_rng(3)
    for trial in range(5):
        n = DEVICES * int(rng.integers(8, 40))
        info = ShardInfo(AXIS, DEVICES, n // DEVICES)
        k1 = rng.integers(0, 8, n).astype(np.int32)  # heavy ties
        k2 = rng.integers(-1, 8, n).astype(np.int32)
        pay = np.arange(n, dtype=np.int32)

        def f(a, b, c):
            res = samplesort_sharded(info, (a, b, c), num_keys=2,
                                     capacity_factor=4.0)
            return res.operands + (res.n_valid[None], res.overflow[None])

        *ops, nv, ov = shard_call(
            mesh, f, jnp.asarray(k1), jnp.asarray(k2), jnp.asarray(pay),
            out_specs=(P(AXIS),) * 3 + (P(AXIS), P(AXIS)),
        )
        assert not np.any(np.asarray(ov)), "unexpected overflow"
        nv = np.asarray(nv)
        slots = np.asarray(ops[0]).shape[0] // DEVICES
        got = []
        for d in range(DEVICES):
            lo, hi = d * slots, d * slots + nv[d]
            got += list(zip(*(np.asarray(o)[lo:hi] for o in ops)))
        assert len(got) == n, f"lost elements {len(got)} != {n}"
        want_order = np.lexsort((pay, k2, k1))
        want_keys = list(zip(k1[want_order], k2[want_order]))
        got_keys = [(a, b) for a, b, _ in got]
        assert got_keys == want_keys, "samplesort key order mismatch"
        assert sorted(p for _, _, p in got) == list(range(n)), "payload lost"
    print("samplesort ok")


def scenario_scatter():
    mesh = make_mesh()
    rng = np.random.default_rng(4)
    n = DEVICES * 32
    info = ShardInfo(AXIS, DEVICES, n // DEVICES)
    perm = rng.permutation(n).astype(np.int32)
    vals = rng.integers(0, 1000, n).astype(np.int32)

    def f_b(i, v):
        return scatter_to_index_bitonic(info, i, (v,))[0]

    out = np.asarray(shard_call(mesh, f_b, jnp.asarray(perm), jnp.asarray(vals)))
    want = np.zeros(n, np.int32)
    want[perm] = vals
    assert np.array_equal(out, want), "bitonic scatter"

    def f_s(i, v):
        (o,), ov = scatter_to_index_samplesort(
            info, i, (v,), valid=jnp.ones_like(i, dtype=bool),
            capacity_factor=4.0,
        )
        return o, ov[None]

    out, ov = shard_call(mesh, f_s, jnp.asarray(perm), jnp.asarray(vals),
                         out_specs=(P(AXIS), P(AXIS)))
    assert not np.any(np.asarray(ov)), "scatter overflow"
    assert np.array_equal(np.asarray(out), want), "samplesort scatter"
    print("scatter ok")


def _check_sa(engine, seed, n_mult):
    mesh = make_mesh()
    rng = np.random.default_rng(seed)
    n = DEVICES * n_mult
    toks = rng.integers(1, 5, n - 1).astype(np.int32)
    s = al.append_sentinel(toks)
    sigma = al.sigma_of(s)
    cfg = DistSAConfig(axis=AXIS, engine=engine, capacity_factor=4.0)
    sa, bwt_arr, row = build_bwt_sharded(jnp.asarray(s), mesh, cfg, sigma=sigma)
    sa = np.asarray(sa)
    want_sa = suffix_array_naive(s)
    assert np.array_equal(sa, want_sa), f"{engine} SA mismatch n={n}"
    want_bwt, want_row = bwt_naive(s)
    assert np.array_equal(np.asarray(bwt_arr), want_bwt), f"{engine} BWT"
    assert int(row) == want_row, f"{engine} row"


def scenario_sa_bitonic():
    for seed, mult in [(0, 2), (1, 8), (2, 17), (3, 64)]:
        _check_sa(BITONIC, seed, mult)
    print("distributed SA/BWT (bitonic) ok")


def scenario_sa_fused():
    """Fused-key / q-gram / discard / radix knobs vs the naive oracle: each
    case must produce the identical SA + BWT.  The exhaustive knob matrix
    runs single-device in tests/test_build_fast.py; this covers the
    distributed-specific paths (both engines, active-aware shuffle, skew
    overflow retry, radix local sort inside shard_map)."""
    mesh = make_mesh()
    rng = np.random.default_rng(13)
    n = DEVICES * 24
    # (sigma_hi, engine, qgram, qgram_words, discard, local_sort)
    cases = [
        (2, BITONIC, True, 2, True, "compare"),
        (2, SAMPLESORT, True, 2, True, "compare"),   # max skew: all keys ==
        (4, BITONIC, True, 2, True, "radix"),
        (4, SAMPLESORT, True, 2, True, "radix"),
        (4, SAMPLESORT, True, 1, False, "compare"),
        (20, BITONIC, False, 1, True, "compare"),
        (20, SAMPLESORT, True, 2, True, "compare"),
        (64, BITONIC, True, 1, False, "radix"),
        (64, SAMPLESORT, False, 1, True, "compare"),
        (64, SAMPLESORT, True, 2, False, "compare"),
    ]
    corpora = {}
    for sigma_hi, engine, qgram, qw, discard, ls in cases:
        if sigma_hi not in corpora:
            toks = rng.integers(1, max(2, sigma_hi), n - 1).astype(np.int32)
            if sigma_hi == 2:
                toks[:] = 1  # unary: maximally repetitive AND skewed
            s = al.append_sentinel(toks)
            corpora[sigma_hi] = (
                s, suffix_array_naive(s), *bwt_naive(s)
            )
        s, want_sa, want_bwt, want_row = corpora[sigma_hi]
        sigma = al.sigma_of(s)
        cfg = DistSAConfig(
            axis=AXIS, engine=engine, capacity_factor=4.0, qgram=qgram,
            qgram_words=qw, discard=discard, local_sort=ls,
        )
        key = (sigma, engine, qgram, qw, discard, ls)
        # unary text: every key equal, range partitioning can't split ->
        # samplesort overflows by design; retry with doubled factor
        # exactly like pipeline.build_index
        for _ in range(4):
            isa = build_isa_sharded(jnp.asarray(s), mesh, cfg, sigma=sigma)
            if not isa_overflowed(isa):
                break
            cfg = cfg._replace(capacity_factor=cfg.capacity_factor * 2)
        else:
            raise AssertionError(f"overflow persists {key}")
        sa, bwt_arr, row = build_bwt_sharded(
            jnp.asarray(s), mesh, cfg, sigma=sigma
        )
        assert np.array_equal(np.asarray(sa), want_sa), key
        assert np.array_equal(np.asarray(bwt_arr), want_bwt), key
        assert int(row) == want_row, key
    print("fused/qgram/discard parity ok")


def scenario_sa_samplesort():
    for seed, mult in [(0, 8), (1, 17), (2, 64)]:
        _check_sa(SAMPLESORT, seed, mult)
    print("distributed SA/BWT (samplesort) ok")


def scenario_dist_fm():
    from repro.core.dist_fm import build_dist_fm_index, dist_count
    from repro.core.fm_index import PAD, count_naive

    mesh = make_mesh()
    rng = np.random.default_rng(7)
    r = 4
    n = DEVICES * 8 * r
    toks = rng.integers(1, 5, n - 1).astype(np.int32)
    s = al.append_sentinel(toks)
    sigma = al.sigma_of(s)
    cfg = DistSAConfig(axis=AXIS, engine=BITONIC)
    _sa, bwt_arr, row = build_bwt_sharded(jnp.asarray(s), mesh, cfg, sigma=sigma)
    idx = build_dist_fm_index(bwt_arr, row, mesh, sigma=sigma, sample_rate=r)
    L = 6
    B = 16
    pats = np.full((B, L), PAD, np.int32)
    lens = rng.integers(1, L + 1, B)
    for b in range(B):
        pats[b, : lens[b]] = rng.integers(1, 5, lens[b])
    got = np.asarray(dist_count(idx, jnp.asarray(pats), mesh))
    want = np.array([count_naive(s, pats[b, : lens[b]]) for b in range(B)])
    assert np.array_equal(got, want), (got, want)
    print("dist FM ok")


def scenario_dist_locate():
    """dist_count AND dist_locate agree with the single-device index built
    over the same corpus, for both the packed and unpacked local layouts."""
    from repro.core.dist_fm import build_dist_fm_index, dist_count, dist_locate
    from repro.core.fm_index import PAD, build_fm_index, count, locate
    from repro.core.suffix_array import suffix_array

    mesh = make_mesh()
    rng = np.random.default_rng(21)
    r = 8
    n = DEVICES * 8 * r
    for sigma_hi, srate in [(5, 8), (17, 4)]:  # packed (4-bit) / unpacked
        toks = rng.integers(1, sigma_hi, n - 1).astype(np.int32)
        s = al.append_sentinel(toks)
        sigma = al.sigma_of(s)
        cfg = DistSAConfig(axis=AXIS, engine=BITONIC)
        sa, bwt_arr, row = build_bwt_sharded(jnp.asarray(s), mesh, cfg,
                                             sigma=sigma)
        idx = build_dist_fm_index(bwt_arr, row, mesh, sigma=sigma,
                                  sample_rate=r, sa=sa, sa_sample_rate=srate)
        sa1 = suffix_array(jnp.asarray(s), sigma)
        fm = build_fm_index(jnp.asarray(np.asarray(bwt_arr)), row, sigma, r,
                            sa=sa1, sa_sample_rate=srate)
        expected_bits = 4 if sigma <= 16 else 0
        assert idx.bits == expected_bits == fm.bits, (idx.bits, fm.bits)
        B, L = 12, 6
        pats = np.full((B, L), PAD, np.int32)
        lens = rng.integers(1, L + 1, B)
        for b in range(B):
            pats[b, : lens[b]] = rng.integers(1, sigma_hi, lens[b])
        got = np.asarray(dist_count(idx, jnp.asarray(pats), mesh))
        want = np.asarray(count(fm, jnp.asarray(pats)))
        assert np.array_equal(got, want), (sigma, got, want)
        k = 32
        dpos, dcnt = dist_locate(idx, jnp.asarray(pats), k, mesh)
        spos, scnt = locate(fm, jnp.asarray(pats), k)
        assert np.array_equal(np.asarray(dcnt), np.asarray(scnt)), sigma
        assert np.array_equal(np.asarray(dpos), np.asarray(spos)), sigma
    print("dist locate ok")


def scenario_pipeline():
    from repro.core.pipeline import build_index
    from repro.core.fm_index import PAD, count_naive

    mesh = make_mesh()
    rng = np.random.default_rng(11)
    for engine in (BITONIC, SAMPLESORT):
        n = 777  # deliberately not divisible by anything
        toks = rng.integers(1, 6, n).astype(np.int32)
        idx = build_index(
            toks, mesh, sample_rate=8,
            sa_config=DistSAConfig(axis=AXIS, engine=engine, capacity_factor=3.0),
        )
        B, L = 8, 5
        pats = np.full((B, L), PAD, np.int32)
        lens = rng.integers(1, L + 1, B)
        for b in range(B):
            pats[b, : lens[b]] = rng.integers(1, 6, lens[b])
        got = np.asarray(idx.count(pats))
        s = al.append_sentinel(toks)
        want = np.array([count_naive(s, pats[b, : lens[b]]) for b in range(B)])
        assert np.array_equal(got, want), (engine, got, want)
    print("pipeline ok")


def scenario_elastic():
    """Elastic re-mesh (DESIGN.md §7): a checkpoint written from an
    8-shard mesh restores byte-identically onto a 4-shard mesh (the
    on-disk format is unsharded; shardings are reapplied on restore)."""
    import tempfile
    from jax.sharding import NamedSharding
    from repro.training.checkpoint import Checkpointer

    assert DEVICES >= 8
    rng = np.random.default_rng(0)
    state = {
        "w": rng.normal(size=(64, 32)).astype(np.float32),
        "m": rng.normal(size=(64, 32)).astype(np.float32),
    }

    mesh8 = jax.make_mesh((8,), (AXIS,), devices=jax.devices()[:8])
    sh8 = NamedSharding(mesh8, P(AXIS, None))
    tree8 = {k: jax.device_put(jnp.asarray(v), sh8) for k, v in state.items()}

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(5, tree8, extra={"mesh": "8"})

        # "lose half the pod": restore onto a 4-device mesh
        mesh4 = jax.make_mesh((4,), (AXIS,), devices=jax.devices()[:4])
        sh4 = NamedSharding(mesh4, P(AXIS, None))
        tmpl = {k: jnp.zeros_like(jnp.asarray(v)) for k, v in state.items()}
        restored, meta = ck.restore(
            tmpl, shardings={k: sh4 for k in state}
        )
        assert meta["step"] == 5 and meta["mesh"] == "8"
        for k in state:
            assert restored[k].sharding.num_devices == 4
            assert np.array_equal(np.asarray(restored[k]), state[k]), k
    print("elastic re-mesh ok")


def scenario_index_io():
    """Index lifecycle across mesh shapes: a distributed index checkpointed
    from an 8-shard mesh restores and answers bit-identically on 8, 4, and
    1 device(s), and a single-device checkpoint restores onto a mesh."""
    import tempfile

    from repro.core.fm_index import PAD
    from repro.core.index_io import describe_index, restore_index, save_index
    from repro.core.pipeline import build_index

    assert DEVICES >= 8
    rng = np.random.default_rng(3)
    r = 8
    n = 8 * 8 * r  # padded length divides parts * r for parts in {8, 4, 1}
    toks = rng.integers(1, 5, n - 1).astype(np.int32)
    mesh8 = jax.make_mesh((8,), (AXIS,), devices=jax.devices()[:8])
    idx = build_index(toks, mesh8, sample_rate=r, sa_sample_rate=4)

    B, L, k = 12, 6, 64
    pats = np.full((B, L), PAD, np.int32)
    lens = rng.integers(1, L + 1, B)
    for b in range(B):
        st = rng.integers(0, n - 1 - lens[b])
        pats[b, : lens[b]] = toks[st : st + lens[b]]
    want_cnt = np.asarray(idx.count(pats))
    want_pos, want_k = (np.asarray(a) for a in idx.locate(pats, k))

    with tempfile.TemporaryDirectory() as d:
        save_index(d, idx)
        info = describe_index(d)
        assert info.kind == "dist_fm" and info.sa_val_bits > 0, info
        mesh4 = jax.make_mesh((4,), (AXIS,), devices=jax.devices()[:4])
        for mesh in (mesh8, mesh4, None):
            rest = restore_index(d, mesh)
            assert np.array_equal(np.asarray(rest.count(pats)), want_cnt), mesh
            pos, cnt = (np.asarray(a) for a in rest.locate(pats, k))
            assert np.array_equal(pos, want_pos), mesh
            assert np.array_equal(cnt, want_k), mesh

    # single-device checkpoint -> distributed restore
    idx1 = build_index(toks, None, sample_rate=r, sa_sample_rate=4)
    with tempfile.TemporaryDirectory() as d:
        save_index(d, idx1)
        # restoring onto a mesh needs the padded length to divide parts * r
        # (n = 512 here, so 4- and 8-shard meshes both qualify)
        for p in (4, 8):
            assert idx1.length % (p * r) == 0, (idx1.length, p, r)
        rest = restore_index(
            d, jax.make_mesh((4,), (AXIS,), devices=jax.devices()[:4])
        )
        assert np.array_equal(np.asarray(rest.count(pats)),
                              np.asarray(idx1.count(pats)))
        pos, cnt = (np.asarray(a) for a in rest.locate(pats, k))
        pos1, cnt1 = (np.asarray(a) for a in idx1.locate(pats, k))
        assert np.array_equal(pos, pos1) and np.array_equal(cnt, cnt1)
    print("index_io re-mesh ok")


def scenario_seg_merge():
    """K-way-vs-rebuild compaction parity with forced host devices present:
    segment builds stay single-device, and the k-way interleave walk must
    produce the identical index (and identical answers) no matter how many
    devices the backend exposes.  Also folds two already-merged
    (multi-document) segments — merged x merged compacts rebuild-free now
    that the left-operand restriction is lifted."""
    from repro.core.fm_index import PAD
    from repro.core.segments import SegmentedIndex

    assert len(jax.devices()) == DEVICES
    rng = np.random.default_rng(41)
    sigma = 5
    chunks = [rng.integers(1, sigma, n).astype(np.int32)
              for n in (3 * DEVICES, 20, 7 * DEVICES, 33)]
    seg_m = SegmentedIndex(sigma, sample_rate=8, sa_sample_rate=4)
    seg_r = SegmentedIndex(sigma, sample_rate=8, sa_sample_rate=4)
    for c in chunks:
        seg_m.append(c)
        seg_r.append(c)

    full = np.concatenate(chunks)
    B, L = 12, 5
    pats = np.full((B, L), PAD, np.int32)
    for b in range(B):
        m = int(rng.integers(1, L + 1))
        st = int(rng.integers(0, len(full) - m))
        pats[b, :m] = full[st : st + m]
    k = 2 * len(full)
    want_c = seg_m.count(pats)
    want_p, want_k = seg_m.locate(pats, k)

    # one k=4 interleave walk folds the whole catalog, no fallback
    assert seg_m.compact(strategy="kway") == 1
    assert seg_m.compact_fallbacks == 0, seg_m.compact_last_fallback_reason
    assert seg_m.compact_strategy_counts == {"kway": 1}
    assert seg_r.compact(strategy="rebuild") == 1
    from repro.core.fm_index import fm_mismatch

    diff = fm_mismatch(seg_m.segments[0].index.fm,
                       seg_r.segments[0].index.fm)
    assert not diff, diff
    assert np.array_equal(seg_m.count(pats), want_c), "answers changed"
    pos, cnt = seg_m.locate(pats, k)
    assert np.array_equal(pos, want_p) and np.array_equal(cnt, want_k)

    # grow two more documents and fold them into a SECOND multi-doc
    # segment (the thresholded compact leaves the big segment alone),
    # then fold merged x merged rebuild-free: the left-operand
    # restriction is lifted when the tokens are context-order safe.
    # The follower's leading document is all-ones (the minimal token),
    # which structurally wins every pad-boundary tie of the left multi —
    # unsafe corpora would fall back to the rebuild, counted, instead
    extra = [np.ones(34, np.int32),
             rng.integers(1, sigma, 21).astype(np.int32)]
    for s in (seg_m, seg_r):
        for c in extra:
            s.append(c)
    assert seg_m.compact(min_tokens=60, strategy="kway") == 1
    assert seg_r.compact(min_tokens=60, strategy="rebuild") == 1
    assert all(s.multi_doc for s in seg_m.segments)
    _, plan = seg_m._plan_run(seg_m.segments, "kway")
    assert plan["reason"] is None, plan["reason"]
    full = np.concatenate([full] + extra)
    c_before = seg_m.count(pats)
    assert seg_m.compact(strategy="kway") == 1  # merged x merged, no rebuild
    assert seg_m.compact_fallbacks == 0, seg_m.compact_last_fallback_reason
    assert seg_m.compact_strategy_counts == {"kway": 3}
    assert seg_r.compact(strategy="rebuild") == 1
    diff = fm_mismatch(seg_m.segments[0].index.fm,
                       seg_r.segments[0].index.fm)
    assert not diff, diff
    assert np.array_equal(seg_m.count(pats), c_before)
    print("seg_merge parity ok")


def scenario_crash_save():
    """Durability under multi-device builds: a segmented catalog built with
    forced host devices present crashes mid-save (injected ``io.write``
    fault), reloads to the last committed generation bit-identically, and
    a clean re-save then commits the new state — same answers, no orphans.
    Honors REPRO_FAULT_SCHEDULE when set (the CI lane passes io.write:3)."""
    import tempfile

    from repro.core.fm_index import PAD
    from repro.core.journal import GenerationJournal
    from repro.core.segments import SegmentedIndex
    from repro.testing import faultinject

    assert len(jax.devices()) == DEVICES
    rng = np.random.default_rng(53)
    sigma = 5
    seg = SegmentedIndex(sigma, sample_rate=8, sa_sample_rate=4)
    chunks = [rng.integers(1, sigma, n).astype(np.int32)
              for n in (4 * DEVICES, 21, 40)]
    for c in chunks[:2]:
        seg.append(c)
    full = np.concatenate(chunks[:2])
    B, L = 8, 5
    pats = np.full((B, L), PAD, np.int32)
    for b in range(B):
        m = int(rng.integers(1, L + 1))
        st = int(rng.integers(0, len(full) - m))
        pats[b, :m] = full[st : st + m]
    want_c = seg.count(pats)

    with tempfile.TemporaryDirectory() as d:
        seg.save(d)  # generation 0, committed clean
        seg.append(chunks[2])
        schedule = (faultinject.arm_from_env()
                    or faultinject.arm(
                        faultinject.FaultSchedule.parse("io.write:3")))
        try:
            seg.save(d)  # crashes mid-stage of generation 1
            raise AssertionError("fault schedule never fired")
        except faultinject.InjectedFault:
            pass
        finally:
            faultinject.arm(None)
        back = SegmentedIndex.load(d)
        man = GenerationJournal(d).committed()
        assert man["generation"] == 0, "torn save must not commit"
        assert not back.degraded, back.quarantined
        assert back.total_tokens == len(full)
        assert np.array_equal(back.count(pats), want_c), "answers changed"
        # recovery swept the staged debris: exactly the committed files
        on_disk = {os.path.relpath(os.path.join(r, f), d).replace(os.sep, "/")
                   for r, _, fs in os.walk(d) for f in fs}
        expected = set(man["files"]) | {
            "CURRENT", "catalog.json", f"gen_{man['generation']:08d}.json"
        }
        assert on_disk == expected, on_disk ^ expected
        # the retried save commits generation 1 with the appended text
        seg.save(d)
        again = SegmentedIndex.load(d)
        assert GenerationJournal(d).committed()["generation"] == 1
        assert again.total_tokens == len(np.concatenate(chunks))
        assert np.array_equal(again.count(pats), seg.count(pats))
    print("crash_save recovery ok")


SCENARIOS = {
    "pipeline": scenario_pipeline,
    "crash_save": scenario_crash_save,
    "seg_merge": scenario_seg_merge,
    "index_io": scenario_index_io,
    "elastic": scenario_elastic,
    "bitonic_sort": scenario_bitonic_sort,
    "shift": scenario_shift,
    "scan": scenario_scan,
    "samplesort": scenario_samplesort,
    "scatter": scenario_scatter,
    "sa_bitonic": scenario_sa_bitonic,
    "sa_fused": scenario_sa_fused,
    "sa_samplesort": scenario_sa_samplesort,
    "dist_fm": scenario_dist_fm,
    "dist_locate": scenario_dist_locate,
}

if __name__ == "__main__":
    name = sys.argv[1]
    if name == "all":
        for k, fn in SCENARIOS.items():
            fn()
    else:
        SCENARIOS[name]()
    print("OK", name)
