"""Per-architecture smoke tests (reduced configs, CPU, 1 device):
one forward/train step asserting output shapes + no NaNs, decode-vs-forward
consistency, and substrate unit tests (optimizer, compression, loader).
"""

import contextlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_reduced_config
from repro.models import transformer as tf
from repro.sharding import single_device_context

LM_ARCHS = [a for a in ARCH_IDS if a != "bwt_index"]


@pytest.fixture(scope="module")
def ctx():
    return single_device_context()


@contextlib.contextmanager
def _skip_if_unbuildable(arch):
    """Reduced configs are sized to fit any CPU host; if an arch's
    test-scale shape still cannot materialise here, record a skip with the
    reason instead of a red suite.  Only resource exhaustion is swallowed —
    real failures on buildable archs still fail."""
    try:
        yield
    except (MemoryError, Exception) as e:  # noqa: B014 - filtered below
        msg = str(e)
        if isinstance(e, MemoryError) or "RESOURCE_EXHAUSTED" in msg \
                or "Out of memory" in msg:
            pytest.skip(f"{arch}: test-scale config does not fit this host")
        raise


def _batch(cfg, rng, B=2, S=16):
    if cfg.frontend != "none":
        return {
            "embeds": jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)), jnp.float32
            ),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
            ),
        }
    toks = rng.integers(0, cfg.vocab_size, (B, S))
    return {
        "tokens": jnp.asarray(toks, jnp.int32),
        "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32),
    }


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch, ctx):
        cfg = get_reduced_config(arch)
        with _skip_if_unbuildable(arch):
            params = tf.init_model(cfg, jax.random.key(0), jnp.float32)
            rng = np.random.default_rng(0)
            batch = _batch(cfg, rng)
            logits = tf.forward(params, batch, cfg, ctx)
        assert logits.shape == (2, 16, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_train_step_decreases_nothing_nan(self, arch, ctx):
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_loop import TrainConfig, init_train_state, make_train_step

        cfg = get_reduced_config(arch)
        tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4))
        with _skip_if_unbuildable(arch):
            state = init_train_state(cfg, jax.random.key(1), tcfg)
            step = make_train_step(cfg, ctx, tcfg)
            rng = np.random.default_rng(1)
            for i in range(2):
                state, metrics = step(state, _batch(cfg, rng))
                assert np.isfinite(float(metrics["loss"])), arch
                assert np.isfinite(float(metrics["grad_norm"])), arch

    def test_decode_step(self, arch, ctx):
        cfg = get_reduced_config(arch)
        with _skip_if_unbuildable(arch):
            params = tf.init_model(cfg, jax.random.key(0), jnp.float32)
            cache = tf.init_cache(cfg, 2, 24, jnp.float32)
            toks = jnp.zeros((2, 1), jnp.int32)
            for pos in range(3):
                logits, cache = tf.decode_step(
                    params, cache, toks, jnp.int32(pos), cfg, ctx
                )
                assert logits.shape == (2, cfg.vocab_size)
                assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_full_config_instantiable(self, arch, ctx):
        """FULL configs are exercised via abstract shapes only (no alloc)."""
        cfg = get_config(arch)
        abstract = tf.abstract_model(cfg)
        n_params = sum(
            int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(abstract)
        )
        assert n_params > 1e9 or arch in ("mamba2_1p3b", "musicgen_medium",
                                          "recurrentgemma_2b", "qwen2p5_3b")
        shardings = tf.model_shardings(cfg, ctx)
        assert jax.tree_util.tree_structure(shardings) == \
            jax.tree_util.tree_structure(abstract)


class TestDecodeMatchesForward:
    """Token-by-token decode must reproduce the full-sequence forward."""

    @pytest.mark.parametrize(
        "arch", ["qwen2p5_3b", "mamba2_1p3b", "recurrentgemma_2b",
                 "minicpm3_4b", "musicgen_medium"]
    )
    def test_consistency(self, arch, ctx):
        cfg = get_reduced_config(arch)
        params = tf.init_model(cfg, jax.random.key(2), jnp.float32)
        rng = np.random.default_rng(2)
        S = 8
        toks = rng.integers(0, cfg.vocab_size, (1, S)).astype(np.int32)
        if cfg.frontend != "none":
            pytest.skip("frontend archs decode over tokens after prefix")
        full = tf.forward(params, {"tokens": jnp.asarray(toks)}, cfg, ctx)
        cache = tf.init_cache(cfg, 1, S, jnp.float32)
        outs = []
        for pos in range(S):
            logits, cache = tf.decode_step(
                params, cache, jnp.asarray(toks[:, pos : pos + 1]),
                jnp.int32(pos), cfg, ctx,
            )
            outs.append(np.asarray(logits, np.float32))
        dec = np.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(full, np.float32), dec, rtol=2e-3, atol=2e-3
        )


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

        cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw_update(grads, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_grad_clip(self):
        from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

        cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0, total_steps=10)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(params)
        _, _, metrics = adamw_update({"w": jnp.full(4, 1e6)}, state, params, cfg)
        assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


class TestCompression:
    def test_error_feedback_unbiased(self):
        from repro.training.compression import compressed_grads, init_error_state

        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=256), jnp.float32)}
        err = init_error_state(g)
        acc = np.zeros(256)
        for _ in range(50):
            g_hat, err = compressed_grads(g, err)
            acc += np.asarray(g_hat["w"])
        # time-averaged compressed gradient converges to the true gradient
        np.testing.assert_allclose(acc / 50, np.asarray(g["w"]), atol=0.02)

    def test_toy_convergence_with_compression(self):
        from repro.training.compression import compressed_grads, init_error_state

        w = jnp.array([4.0, -2.0, 1.0])
        err = init_error_state({"w": w})
        lr = 0.05
        for _ in range(200):
            g = {"w": 2 * w}
            g_hat, err = compressed_grads(g, err)
            w = w - lr * g_hat["w"]
        assert float(jnp.abs(w).max()) < 0.05


class TestLoader:
    def test_deterministic_and_resumable(self):
        from repro.data.loader import LoaderConfig, TokenLoader

        toks = np.arange(10000, dtype=np.int32) % 97 + 1
        l1 = TokenLoader(toks, LoaderConfig(4, 32, seed=5))
        l2 = TokenLoader(toks, LoaderConfig(4, 32, seed=5))
        b1 = l1.batch(17)
        b2 = l2.batch(17)  # fresh instance, same (seed, step)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        assert np.array_equal(b1["labels"], b2["labels"])

    def test_labels_shifted(self):
        from repro.data.loader import LoaderConfig, TokenLoader

        toks = np.arange(1000, dtype=np.int32) + 1
        b = TokenLoader(toks, LoaderConfig(2, 16)).batch(0)
        assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
