"""Property-based tests (hypothesis) for the system's invariants.

Collection must survive machines without ``hypothesis``: the property tests
are defined only when it imports, a skip-with-reason placeholder records the
gap otherwise (via ``pytest.importorskip``), and a deterministic fallback
sweep below exercises the same invariants on fixed seeds either way.
"""

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import alphabet as al
from repro.core.bwt import bwt, inverse_bwt
from repro.core.fm_index import PAD, build_fm_index, count, count_naive
from repro.core.suffix_array import (
    isa_prefix_doubling,
    sa_from_isa,
    suffix_array,
    suffix_array_naive,
)


def _prep(toks):
    s = al.append_sentinel(np.array(toks, dtype=np.int32))
    return s, al.sigma_of(s)


def _check_sa_is_permutation_and_sorted(toks):
    """SA is a permutation of [0, n) and orders suffixes lexicographically."""
    s, sigma = _prep(toks)
    sa = np.asarray(suffix_array(jnp.asarray(s), sigma))
    n = len(s)
    assert sorted(sa.tolist()) == list(range(n))
    suffixes = [s[i:].tolist() for i in sa]
    assert suffixes == sorted(suffixes)


def _check_sa_matches_naive(toks):
    s, sigma = _prep(toks)
    sa = np.asarray(suffix_array(jnp.asarray(s), sigma))
    assert np.array_equal(sa, suffix_array_naive(s))


def _check_isa_sa_inverse(toks):
    s, sigma = _prep(toks)
    isa = isa_prefix_doubling(jnp.asarray(s), sigma)
    sa = sa_from_isa(isa)
    n = len(s)
    assert np.array_equal(np.asarray(sa)[np.asarray(isa)], np.arange(n))


def _check_bwt_roundtrip(toks):
    """bwt is a permutation of the text and inverts exactly (paper §2.1)."""
    s, sigma = _prep(toks)
    b, row = bwt(jnp.asarray(s), sigma)
    assert sorted(np.asarray(b).tolist()) == sorted(s.tolist())
    rec = inverse_bwt(b, row, sigma)
    assert np.array_equal(np.asarray(rec), s)


def _check_fm_count_matches_substring_count(toks, pattern):
    s, sigma = _prep(toks)
    b, row = bwt(jnp.asarray(s), sigma)
    fm = build_fm_index(b, row, sigma, sample_rate=4)
    pat = np.array(pattern, dtype=np.int32)
    pp = np.full((1, 8), PAD, np.int32)
    pp[0, : len(pat)] = pat
    got = int(count(fm, jnp.asarray(pp))[0])
    assert got == count_naive(s, pat)


def _check_occurrences_sum_to_text_length(toks):
    """Σ_c count(c as 1-gram) == n - 1 (every non-sentinel position)."""
    s, sigma = _prep(toks)
    b, row = bwt(jnp.asarray(s), sigma)
    fm = build_fm_index(b, row, sigma, sample_rate=4)
    pats = np.full((sigma - 1, 1), PAD, np.int32)
    pats[:, 0] = np.arange(1, sigma)
    total = int(np.asarray(count(fm, jnp.asarray(pats))).sum())
    assert total == len(s) - 1


if HAVE_HYPOTHESIS:
    tokens_strategy = st.lists(
        st.integers(min_value=1, max_value=6), min_size=1, max_size=80
    )

    @settings(max_examples=40, deadline=None)
    @given(tokens_strategy)
    def test_sa_is_permutation_and_sorted(toks):
        _check_sa_is_permutation_and_sorted(toks)

    @settings(max_examples=30, deadline=None)
    @given(tokens_strategy)
    def test_sa_matches_naive(toks):
        _check_sa_matches_naive(toks)

    @settings(max_examples=30, deadline=None)
    @given(tokens_strategy)
    def test_isa_sa_inverse(toks):
        _check_isa_sa_inverse(toks)

    @settings(max_examples=30, deadline=None)
    @given(tokens_strategy)
    def test_bwt_roundtrip(toks):
        _check_bwt_roundtrip(toks)

    @settings(max_examples=20, deadline=None)
    @given(
        tokens_strategy,
        st.lists(st.integers(min_value=1, max_value=6), min_size=1, max_size=5),
    )
    def test_fm_count_matches_substring_count(toks, pattern):
        _check_fm_count_matches_substring_count(toks, pattern)

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=3),
                    min_size=2, max_size=40))
    def test_occurrences_sum_to_text_length(toks):
        _check_occurrences_sum_to_text_length(toks)

else:

    def test_property_suite_requires_hypothesis():
        pytest.importorskip(
            "hypothesis",
            reason="hypothesis not installed; deterministic fallback below "
                   "still covers the invariants",
        )


# --- deterministic fallback: the same invariants on fixed random seeds, so
# the module asserts something real even without hypothesis installed ---


@pytest.mark.parametrize("seed", range(6))
def test_invariants_fixed_seeds(seed):
    rng = np.random.default_rng(seed)
    toks = rng.integers(1, 7, int(rng.integers(1, 81))).tolist()
    _check_sa_is_permutation_and_sorted(toks)
    _check_sa_matches_naive(toks)
    _check_isa_sa_inverse(toks)
    _check_bwt_roundtrip(toks)
    pattern = rng.integers(1, 7, int(rng.integers(1, 6))).tolist()
    _check_fm_count_matches_substring_count(toks, pattern)
    _check_occurrences_sum_to_text_length(rng.integers(1, 4, 40).tolist())
