"""Multi-device correctness, via subprocesses with forced host devices
(the main pytest process keeps the default single device — dry-run flags
must never leak into smoke tests/benches)."""

import os
import subprocess
import sys

import pytest

DRIVER = os.path.join(os.path.dirname(__file__), "dist_driver.py")

SCENARIOS = [
    "bitonic_sort",
    "shift",
    "scan",
    "samplesort",
    "scatter",
    "sa_bitonic",
    "sa_fused",
    "sa_samplesort",
    "dist_fm",
    "dist_locate",
    "pipeline",
    "elastic",
]


def _run(scenario: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, DRIVER, scenario, str(devices)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, (
        f"{scenario} failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
        f"STDERR:{proc.stderr[-3000:]}"
    )


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_distributed_scenario(scenario):
    _run(scenario)


def test_nonpow2_device_count_samplesort():
    """Sample sort has no power-of-two requirement (bitonic does)."""
    _run("samplesort", devices=6)


def test_main_process_sees_one_device():
    import jax

    assert len(jax.devices()) == 1
