"""Chunked (flash-style) attention vs the naive oracle, fp8 KV-cache decode,
and the competitor algorithm — the §Perf-critical numerics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_reduced_config
from repro.models import blocks
from repro.sharding import single_device_context


@pytest.fixture(scope="module")
def ctx():
    return single_device_context()


class TestChunkedAttention:
    @pytest.mark.parametrize("window", [0, 512, 2048])
    def test_gqa_chunked_matches_naive(self, window):
        rng = np.random.default_rng(0)
        B, S, H, Hkv, hd = 2, 2048, 4, 2, 16
        q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.float32)
        naive = blocks._attend(q, k, v, blocks._causal_mask(S, S, window=window))
        chunked = blocks._attend_chunked(q, k, v, window=window)
        np.testing.assert_allclose(
            np.asarray(naive, np.float32), np.asarray(chunked, np.float32),
            rtol=1e-4, atol=1e-4,
        )

    def test_mla_chunked_matches_naive(self, ctx):
        cfg = get_reduced_config("minicpm3_4b")
        from repro.models.transformer import init_model

        params = init_model(cfg, jax.random.key(0), jnp.float32)
        # pull one MLA layer's params out of the stacked blocks
        p = jax.tree_util.tree_map(
            lambda x: x[0], params["blocks"]["s0"]["mixer"]
        )
        rng = np.random.default_rng(1)
        B, S = 1, 2048
        x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.1, jnp.float32)
        from repro.models.common import apply_rope

        q_nope, q_rope = blocks._mla_q(p, x, cfg)
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        ckv, k_rope = blocks._mla_kv_latent(p, x, cfg)
        k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
        naive = blocks._mla_attend(
            p, q_nope, q_rope, ckv, k_rope, cfg, blocks._causal_mask(S, S)
        )
        chunked = blocks._mla_attend_chunked(p, q_nope, q_rope, ckv, k_rope, cfg)
        np.testing.assert_allclose(
            np.asarray(naive, np.float32), np.asarray(chunked, np.float32),
            rtol=2e-3, atol=2e-3,
        )


class TestFp8KVCache:
    @pytest.mark.parametrize("arch", ["qwen2p5_3b", "minicpm3_4b"])
    def test_decode_with_fp8_cache_close_to_bf16(self, arch, ctx):
        from repro.models import transformer as tf

        cfg = get_reduced_config(arch)
        params = tf.init_model(cfg, jax.random.key(2), jnp.float32)
        rng = np.random.default_rng(2)
        toks = rng.integers(0, cfg.vocab_size, (1, 6)).astype(np.int32)

        outs = {}
        for dt in (jnp.float32, jnp.float8_e4m3fn):
            cache = tf.init_cache(cfg, 1, 8, dt)
            logits_seq = []
            for pos in range(6):
                logits, cache = tf.decode_step(
                    params, cache, jnp.asarray(toks[:, pos : pos + 1]),
                    jnp.int32(pos), cfg, ctx,
                )
                logits_seq.append(np.asarray(logits, np.float32))
            outs[str(dt)] = np.stack(logits_seq)
        a, b = outs.values()
        assert np.isfinite(b).all()
        # fp8 quantisation error stays small relative to logit scale
        denom = np.maximum(np.abs(a).max(), 1e-6)
        assert np.abs(a - b).max() / denom < 0.15


class TestCompetitor:
    """Menon et al. ranged direct-comparison construction (the paper's
    Table 2 baseline) must be exactly correct too."""

    @pytest.mark.parametrize("seed", range(4))
    def test_matches_naive(self, seed):
        from repro.core import alphabet as al
        from repro.core.competitor import suffix_array_rpgi
        from repro.core.suffix_array import suffix_array_naive

        rng = np.random.default_rng(seed)
        s = al.append_sentinel(
            rng.integers(1, rng.integers(2, 7), rng.integers(2, 120))
            .astype(np.int32)
        )
        got = np.asarray(suffix_array_rpgi(jnp.asarray(s)))
        assert np.array_equal(got, suffix_array_naive(s))

    def test_repetitive_worst_case(self):
        from repro.core import alphabet as al
        from repro.core.competitor import suffix_array_rpgi
        from repro.core.suffix_array import suffix_array_naive

        s = al.append_sentinel(np.tile([1, 1, 2], 80).astype(np.int32))
        got = np.asarray(suffix_array_rpgi(jnp.asarray(s)))
        assert np.array_equal(got, suffix_array_naive(s))

    def test_agrees_with_ours(self):
        from repro.core import alphabet as al
        from repro.core.competitor import bwt_rpgi
        from repro.core.bwt import bwt

        rng = np.random.default_rng(9)
        s = al.append_sentinel(rng.integers(1, 5, 200).astype(np.int32))
        b1, r1 = bwt(jnp.asarray(s), al.sigma_of(s))
        b2, r2 = bwt_rpgi(jnp.asarray(s))
        assert np.array_equal(np.asarray(b1), np.asarray(b2))
        assert int(r1) == int(r2)
